"""§4.2/§4.3 lower-set families."""

import itertools
import random

import pytest

from repro.core.graph import chain, from_cost_lists
from repro.core.lower_sets import (
    all_lower_sets,
    count_lower_sets,
    pruned_lower_sets,
    segment_lower_sets,
)

from conftest import random_dag
from helpers import brute_lower_sets


def test_all_lower_sets_matches_bruteforce(rng):
    for trial in range(120):
        g = random_dag(rng, rng.randint(1, 8), topo_ids=(trial % 2 == 0))
        assert set(all_lower_sets(g)) == brute_lower_sets(g), trial


def test_all_lower_sets_nontopological_ids():
    # regression: ideal enumeration must not assume ids are topological
    g = from_cost_lists([1, 1, 1], [1, 1, 1], [(2, 1), (1, 0)])  # 2 → 1 → 0
    assert set(all_lower_sets(g)) == brute_lower_sets(g)


def test_limit_raises():
    # antichain of 24 isolated nodes → 2^24 lower sets > limit
    g = from_cost_lists([1] * 24, [1] * 24, [])
    with pytest.raises(RuntimeError):
        all_lower_sets(g, limit=10_000)


def test_pruned_is_subset_with_size_bound(rng):
    for _ in range(60):
        g = random_dag(rng, rng.randint(1, 8))
        fam = pruned_lower_sets(g)
        assert len(fam) <= g.n + 2  # {L^v} ∪ {∅, V}  (§4.3: #𝓛^Pruned = #V)
        allf = brute_lower_sets(g)
        assert set(fam) <= allf
        assert frozenset() in fam and frozenset(range(g.n)) in fam


def test_pruned_principal_sets_definition(rng):
    for _ in range(30):
        g = random_dag(rng, 7)
        fam = set(pruned_lower_sets(g))
        for v in range(g.n):
            Lv = frozenset(
                w for w in range(g.n) if v in g.reachable_from(w)
            )
            assert Lv in fam


def test_segment_lower_sets_are_lower_sets(rng):
    for _ in range(30):
        g = random_dag(rng, 8)
        for L in segment_lower_sets(g):
            assert g.is_lower_set(L)


def test_chain_lattice_is_prefixes():
    g = chain(6)
    fam = all_lower_sets(g)
    assert fam == [frozenset(range(k)) for k in range(7)]
    # on a chain the pruned family loses nothing
    assert set(pruned_lower_sets(g)) == set(fam)


def test_count_bounds(rng):
    for _ in range(20):
        g = random_dag(rng, 6)
        c = count_lower_sets(g)
        assert g.n + 1 <= c <= 2 ** g.n
