"""Logical-axis sharding rules (DP/TP/EP/SP over ("pod", "data", "model")).

Models annotate activations with *logical* axis names; a rules table maps
them to mesh axes.  Changing the table re-shards the whole model — this is
the knob the §Perf hillclimb turns.

Default mapping:

  batch    → ("pod", "data")   data parallelism (hierarchical across pods)
  seq      → None              (sequence kept local for training shapes)
  seq_sp   → "data"            sequence parallelism for long-context decode
  model    → "model"           d_model kept replicated by default; the TP
                               split lives on heads / ffn / vocab instead
  heads    → "model"           tensor parallelism over attention heads
  kv_heads → "model"           (GQA: kv heads ≤ TP size is handled by rules)
  ffn      → "model"           MLP hidden dim
  experts  → "model"           expert parallelism
  vocab    → "model"           embedding / logits split
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.compat import get_abstract_mesh


Rules = Dict[str, Any]  # logical name -> mesh axis (str | tuple | None)

DEFAULT_RULES: Rules = {
    "batch": ("pod", "data"),
    "seq": None,
    "seq_sp": "data",
    "seq_act": "model",  # Megatron-style sequence parallelism: the residual
    #                      stream between layer groups lives S/tp per device
    "model": None,
    "heads": "model",
    "kv_heads": "model",
    "ffn": "model",
    "experts": "model",
    "expert_cap": "model",  # fallback: shard expert capacity rows when the
    #                         expert count doesn't divide the model axis
    "vocab": "model",
    "state": None,
}

# §Perf hillclimb alternative: NO tensor parallelism — the "model" mesh axis
# joins data parallelism and params are fully sharded (ZeRO-3).  For models
# whose per-chip matmul shards would be tiny under tp=16 (≤ ~4B params at 256
# chips), this removes every activation-cotangent all-reduce and replaces it
# with per-layer weight all-gathers an order of magnitude smaller.
DP_ONLY_RULES: Rules = {
    **DEFAULT_RULES,
    "batch": ("pod", "data", "model"),
    "seq_act": None,
    "heads": None,
    "kv_heads": None,
    "ffn": None,
    "experts": None,
    "expert_cap": None,
    "vocab": None,
}

# MoE hybrid: attention/dense parts ZeRO-sharded over data (no TP — their
# per-chip shards are tiny next to the experts), experts stay EP over the
# model axis with the all-to-all schedule.
DP_ATTN_RULES: Rules = {
    **DEFAULT_RULES,
    "seq_act": None,
    "heads": None,
    "kv_heads": None,
    "ffn": None,
    # vocab stays TP over "model": un-sharding it makes every chip hold the
    # full (B_loc, S, V) logits — 40 GB/chip at this cell's shape.
}

# Active rules — module-level so layer code stays signature-light; the
# launcher swaps them per run (hillclimb knob).
_ACTIVE_RULES: Rules = dict(DEFAULT_RULES)


def set_rules(rules: Rules) -> None:
    global _ACTIVE_RULES
    _ACTIVE_RULES = dict(rules)


def get_rules() -> Rules:
    return dict(_ACTIVE_RULES)


def _mesh_axes(mesh: Optional[Mesh]) -> Tuple[str, ...]:
    if mesh is not None:
        return tuple(mesh.axis_names)
    env = get_abstract_mesh()
    try:
        return tuple(env.axis_names) if env is not None else ()
    except Exception:
        return ()


def resolve_spec(
    logical: Sequence[Optional[str]],
    axis_sizes: Dict[str, int],
    shape: Optional[Sequence[int]] = None,
    rules: Optional[Rules] = None,
    pad_dims: Sequence[int] = (),
) -> P:
    """Logical names → PartitionSpec under ``rules`` and abstract axis sizes.

    The mesh-free core of :func:`resolve`, shared with the planners'
    byte accounting (``launch.plan`` budgets per-device bytes through this
    exact function, so the sharding the model compiles to and the sharding
    the DP budgets against cannot drift apart).

    With ``shape``, divisibility is checked inline so an axis rejected on one
    dim (e.g. "model" on 40 experts) stays available for a later dim (e.g.
    the expert-capacity fallback) instead of being consumed and dropped.
    Dims listed in ``pad_dims`` skip the divisibility check — GSPMD pads
    those (sequence dims at odd lengths), and ``local_shape``'s ceil
    division accounts the padded shard.
    """
    rules = _ACTIVE_RULES if rules is None else rules
    axes = set(axis_sizes)
    pad = set(pad_dims)
    used: set = set()
    spec = []
    for i, name in enumerate(logical):
        if name is None:
            spec.append(None)
            continue
        target = rules.get(name)
        if target is None:
            spec.append(None)
            continue
        if isinstance(target, str):
            target = (target,)
        eff = []
        dim = shape[i] if shape is not None and i < len(shape) else None
        prod = 1
        for a in target:
            if a not in axes or a in used:
                continue
            if (dim is not None and i not in pad
                    and dim % (prod * axis_sizes.get(a, 1)) != 0):
                continue  # this axis would not divide — leave it available
            eff.append(a)
            prod *= axis_sizes.get(a, 1)
        used.update(eff)
        eff = tuple(eff)
        spec.append(eff if len(eff) > 1 else (eff[0] if eff else None))
    return P(*spec)


def resolve(
    logical: Sequence[Optional[str]],
    mesh: Optional[Mesh] = None,
    shape: Optional[Sequence[int]] = None,
) -> P:
    """Logical names → PartitionSpec under the active rules + mesh axes."""
    src = mesh if mesh is not None else get_abstract_mesh()
    sizes = _axis_sizes(src)
    for a in _mesh_axes(mesh):
        sizes.setdefault(a, 1)
    return resolve_spec(logical, sizes, shape=shape)


def _axis_sizes(mesh) -> Dict[str, int]:
    try:
        return dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        try:
            return dict(mesh.shape)
        except Exception:
            return {}


def drop_indivisible(spec: P, shape: Tuple[int, ...], axis_sizes: Dict[str, int]) -> P:
    """Replicate any dim the mesh axes don't divide evenly (e.g. kv_heads=8
    on a 16-way model axis, or an odd vocab).  GSPMD *would* pad, but padded
    shards waste memory/compute — replication is the perf-correct fallback."""
    entries = tuple(spec) + (None,) * (len(shape) - len(spec))
    out = []
    for dim, entry in zip(shape, entries):
        if entry is None:
            out.append(None)
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        total = 1
        for a in axes:
            total *= axis_sizes.get(a, 1)
        out.append(entry if total > 0 and dim % total == 0 else None)
    return P(*out)


def shard(x, *logical: Optional[str]):
    """with_sharding_constraint by logical axis names (no-op without a mesh)."""
    try:
        mesh = get_abstract_mesh()
        if mesh is None or not mesh.axis_names or mesh.empty:
            return x
    except Exception:
        return x
    spec = resolve(logical, shape=tuple(x.shape))
    try:
        return jax.lax.with_sharding_constraint(x, spec)
    except Exception:
        return x


# ---------------------------------------------------------------------------
# Parameter sharding: map a param-tree path to a PartitionSpec.
# ---------------------------------------------------------------------------


def param_spec(path: str, shape: Tuple[int, ...]) -> P:
    """Sharding rule for one parameter, keyed on its tree path.

    Conventions (matching repro.models param names):
      embed / unembed   : (vocab, d_model)          → vocab over "model"
      wq/wk/wv          : (d_model, heads·dh)       → out dim over "model"
      wo                : (heads·dh, d_model)       → in dim over "model"
      w_gate/w_up       : (d_model, d_ff)           → d_ff over "model"
      w_down            : (d_ff, d_model)           → d_ff over "model"
      experts.*         : (E, …)                    → E over "model"
      norms / biases / scalars                      → replicated
    """
    rules = _ACTIVE_RULES

    def ax(name):
        t = rules.get(name)
        return t if t is not None else None

    if len(shape) == 0 or min(shape) == 0:
        return P()
    last = path.split("/")[-1]
    if "expert" in path:
        # stacked experts: leading E axis
        spec = [ax("experts")] + [None] * (len(shape) - 1)
        if last in ("w_gate", "w_up") and len(shape) == 3:
            spec[2] = None  # E already takes "model"
        return P(*spec)
    if last in ("embed", "unembed", "lm_head"):
        return P(ax("vocab"), None) if len(shape) == 2 else P()
    if last in ("wq", "wk", "wv", "wqkv"):
        return P(None, ax("heads")) if len(shape) >= 2 else P(ax("heads"))
    if last == "wo":
        return P(ax("heads"), None)
    if last in ("w_gate", "w_up", "w13"):
        return P(None, ax("ffn"))
    if last in ("w_down", "w2"):
        return P(ax("ffn"), None)
    if last in ("in_proj", "x_proj", "dt_proj"):
        return P(None, ax("ffn")) if len(shape) == 2 else P()
    if last == "out_proj":
        return P(ax("ffn"), None) if len(shape) == 2 else P()
    return P(*([None] * len(shape)))


def stacked_param_spec(path: str, shape: Tuple[int, ...]) -> P:
    """Same, for layer-stacked params with a leading [n_layers] axis."""
    inner = param_spec(path, shape[1:])
    return P(None, *inner)


def tree_param_specs(params, stacked_prefixes: Sequence[str] = ("layers",)):
    """PartitionSpec pytree matching a parameter pytree."""

    def visit(path_tuple, leaf):
        keys = []
        for p in path_tuple:
            if hasattr(p, "key"):
                keys.append(str(p.key))
            elif hasattr(p, "idx"):
                keys.append(str(p.idx))
            else:
                keys.append(str(p))
        path = "/".join(keys)
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        if any(path.startswith(pref) for pref in stacked_prefixes) and len(shape) >= 1:
            return stacked_param_spec(path, shape)
        return param_spec(path, shape)

    return jax.tree_util.tree_map_with_path(visit, params)


def fsdp_extend(spec: P, shape: Tuple[int, ...], axis_sizes: Dict[str, int],
                fsdp_axis: str = "data", min_elems: int = 1 << 16) -> P:
    """ZeRO-3/FSDP: additionally shard the largest still-replicated dim of a
    big tensor over the data axis.  Keeps small tensors (norms, biases)
    replicated."""
    n = 1
    for d in shape:
        n *= d
    if n < min_elems or fsdp_axis not in axis_sizes:
        return spec
    entries = list(tuple(spec) + (None,) * (len(shape) - len(spec)))
    # never reuse an axis that already shards some dim
    for e in entries:
        taken = e if isinstance(e, tuple) else (e,)
        if fsdp_axis in taken:
            return spec
    size = axis_sizes[fsdp_axis]
    # largest unsharded, divisible dim
    best, best_dim = -1, -1
    for i, (d, e) in enumerate(zip(shape, entries)):
        if e is None and d % size == 0 and d > best_dim:
            best, best_dim = i, d
    if best < 0:
        return spec
    entries[best] = fsdp_axis
    return P(*entries)


# ---------------------------------------------------------------------------
# Per-device byte accounting (the paper's budget B is ONE accelerator's
# memory, §3): everything that budgets bytes — the traced carriers
# (core.jaxpr_graph), BlockGraph annotations, and the launchers' chain
# graphs (launch.plan) — prices tensors through these helpers, so there is
# exactly one definition of "per-device bytes" in the system.
# ---------------------------------------------------------------------------


def axis_sizes_of(mesh) -> Dict[str, int]:
    """Axis-name → size for a Mesh/AbstractMesh, or a dict passed through.

    Accepting a plain ``{"data": 8, "model": 2}`` dict lets the byte
    accounting (and with it the whole planning pipeline) run without any
    real devices — only the lowerings need a concrete ``Mesh``.
    """
    if mesh is None:
        return {}
    if isinstance(mesh, dict):
        return {str(k): int(v) for k, v in mesh.items()}
    return _axis_sizes(mesh)


def _entry_shards(entry, axis_sizes: Dict[str, int]) -> int:
    if entry is None:
        return 1
    axes = entry if isinstance(entry, tuple) else (entry,)
    k = 1
    for a in axes:
        k *= max(1, int(axis_sizes.get(a, 1)))
    return k


def local_shape(
    shape: Sequence[int], spec, axis_sizes: Dict[str, int]
) -> Tuple[int, ...]:
    """Per-device shard shape of a global ``shape`` under ``spec``.

    GSPMD semantics: each sharded dim is ceil-divided by the product of its
    mesh axis sizes (padding counts — padded shards still occupy HBM).
    """
    entries = tuple(spec) if spec is not None else ()
    entries = entries + (None,) * (len(shape) - len(entries))
    return tuple(
        -(-int(d) // _entry_shards(e, axis_sizes))
        for d, e in zip(shape, entries)
    )


def num_shards(shape: Sequence[int], spec, axis_sizes: Dict[str, int]) -> int:
    """Effective #devices a tensor is split across: global/local elems."""
    loc = local_shape(shape, spec, axis_sizes)
    g = l = 1
    for d, ld in zip(shape, loc):
        g *= max(1, int(d))
        l *= max(1, int(ld))
    return max(1, g // max(1, l))


def local_bytes(
    shape: Sequence[int], spec, axis_sizes: Dict[str, int], itemsize: int
) -> int:
    """Per-device bytes of one tensor (ceil-divided shard × itemsize)."""
    n = 1
    for d in local_shape(shape, spec, axis_sizes):
        n *= max(1, int(d))
    return n * int(itemsize)


def normalize_spec(sharding) -> P:
    """NamedSharding | PartitionSpec | None → a plain PartitionSpec."""
    if sharding is None:
        return P()
    if isinstance(sharding, NamedSharding):
        return sharding.spec
    if isinstance(sharding, P):
        return sharding
    raise TypeError(
        f"expected PartitionSpec/NamedSharding/None, got {type(sharding).__name__}"
    )


def sharded_aval_bytes(aval, spec, axis_sizes: Dict[str, int]) -> int:
    """Per-device byte size of one aval under ``spec`` (replicated: global)."""
    import numpy as _np

    if not hasattr(aval, "shape") or not hasattr(aval, "dtype"):
        return 1
    return local_bytes(
        aval.shape, spec, axis_sizes, _np.dtype(aval.dtype).itemsize
    )


# ---------------------------------------------------------------------------
# Conservative sharding propagation over a jaxpr.
#
# The traced carrier needs a per-equation output sharding to emit per-device
# M_v.  Full GSPMD propagation lives inside XLA; here we follow the specs
# through the primitives whose propagation is unambiguous (elementwise /
# same-shape, transpose, broadcast, reductions, dot_general) and fall back
# to **replicated** everywhere else.  Replicated is the conservative
# direction for a memory planner: per-device bytes are over-, never
# under-estimated, so a plan that fits the modeled budget fits the machine.
# ---------------------------------------------------------------------------

_REDUCE_PRIMS = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
    "reduce_and", "reduce_or", "argmax", "argmin",
})


def _spec_entries(spec: Optional[P], ndim: int) -> Tuple:
    entries = tuple(spec) if spec is not None else ()
    return entries + (None,) * (ndim - len(entries))


def propagate_eqn_specs(
    closed_jaxpr, in_specs: Sequence[P], axis_sizes: Dict[str, int]
):
    """Per-equation output PartitionSpecs for a ClosedJaxpr.

    ``in_specs`` aligns with ``jaxpr.invars``.  Returns a list (one entry
    per equation) of tuples of PartitionSpecs aligned with the equation's
    outvars.  Unknown primitives propagate replicated (see module note).
    """
    from jax.extend import core as _jcore

    jaxpr = closed_jaxpr.jaxpr
    env: Dict[Any, P] = {}
    for v in jaxpr.constvars:
        env[v] = P()
    for v, s in zip(jaxpr.invars, in_specs):
        env[v] = normalize_spec(s)

    def spec_of(var) -> P:
        # Literals (e.g. the divisor of jnp.mean) are unhashable on older
        # JAX and always replicated — never probe the env with one
        if isinstance(var, _jcore.Literal):
            return P()
        return env.get(var, P())

    out: list = []
    for eqn in jaxpr.eqns:
        name = eqn.primitive.name
        specs = None
        try:
            if name == "dot_general":
                specs = (_dot_general_spec(eqn, spec_of),)
            elif name == "transpose":
                perm = eqn.params["permutation"]
                ent = _spec_entries(spec_of(eqn.invars[0]),
                                    len(eqn.invars[0].aval.shape))
                specs = (P(*[ent[p] for p in perm]),)
            elif name == "broadcast_in_dim":
                specs = (_broadcast_spec(eqn, spec_of),)
            elif name in _REDUCE_PRIMS:
                axes = set(eqn.params.get("axes", ()))
                iv = eqn.invars[0]
                ent = _spec_entries(spec_of(iv), len(iv.aval.shape))
                specs = (P(*[e for i, e in enumerate(ent) if i not in axes]),)
        except Exception:
            specs = None
        if specs is None:
            specs = tuple(_same_shape_spec(ov, eqn, spec_of)
                          for ov in eqn.outvars)
        for ov, s in zip(eqn.outvars, specs):
            if type(ov).__name__ != "DropVar":
                env[ov] = s
        out.append(specs)
    return out


def _same_shape_spec(ov, eqn, spec_of) -> P:
    """Shape-preserving passthrough: adopt the most-sharded operand whose
    shape equals the output's; replicated otherwise."""
    shape = getattr(getattr(ov, "aval", None), "shape", None)
    if shape is None:
        return P()
    best, best_k = P(), 1
    for iv in eqn.invars:
        if getattr(getattr(iv, "aval", None), "shape", None) != shape:
            continue
        s = spec_of(iv)
        # rank operands by how many ways they split the tensor
        k = num_shards(shape, s, {a: 2 for a in _spec_axes(s)})
        if k > best_k:
            best, best_k = s, k
    return best


def _spec_axes(spec: P):
    axes = []
    for e in tuple(spec):
        if e is None:
            continue
        axes.extend(e if isinstance(e, tuple) else (e,))
    return axes


def _dot_general_spec(eqn, spec_of) -> P:
    """Output spec of dot_general: (batch…, lhs-free…, rhs-free…) dims keep
    their operand's sharding; contracted dims disappear."""
    lhs, rhs = eqn.invars[0], eqn.invars[1]
    (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
    l_ent = _spec_entries(spec_of(lhs), len(lhs.aval.shape))
    r_ent = _spec_entries(spec_of(rhs), len(rhs.aval.shape))
    out = [l_ent[i] for i in lb]
    out += [l_ent[i] for i in range(len(l_ent)) if i not in set(lc) | set(lb)]
    out += [r_ent[i] for i in range(len(r_ent)) if i not in set(rc) | set(rb)]
    # one mesh axis must not shard two output dims (lhs/rhs may both carry it)
    seen: set = set()
    clean = []
    for e in out:
        axes = e if isinstance(e, tuple) else ((e,) if e is not None else ())
        if any(a in seen for a in axes):
            clean.append(None)
            continue
        seen.update(axes)
        clean.append(e)
    return P(*clean)


def _broadcast_spec(eqn, spec_of) -> P:
    iv = eqn.invars[0]
    bdims = eqn.params["broadcast_dimensions"]
    in_shape = iv.aval.shape
    ent = _spec_entries(spec_of(iv), len(in_shape))
    out_shape = eqn.outvars[0].aval.shape
    out = [None] * len(out_shape)
    for i, j in enumerate(bdims):
        if in_shape[i] == out_shape[j]:
            out[j] = ent[i]
    return P(*out)


def named_sharding_tree(params, mesh: Mesh, fsdp: bool = False,
                        fsdp_axes: Tuple[str, ...] = ("data",), **kw):
    specs = tree_param_specs(params, **kw)
    sizes = _axis_sizes(mesh)

    def to_sharding(spec, leaf):
        shape = tuple(leaf.shape) if hasattr(leaf, "shape") else ()
        p = drop_indivisible(spec, shape, sizes)
        if fsdp:
            for ax in fsdp_axes:
                p = fsdp_extend(p, shape, sizes, fsdp_axis=ax)
        return NamedSharding(mesh, p)

    return jax.tree_util.tree_map(to_sharding, specs, params)
