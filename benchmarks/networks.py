"""Graph abstractions of the paper's seven benchmark networks (§5, Table 1).

Node counts match Table 1 (#V column): PSPNet 385, U-Net 60, ResNet50 176,
ResNet152 516, VGG19 46, DenseNet161 568, GoogLeNet 134.  Topologies follow
each architecture's connectivity (residual blocks, dense blocks, U-skips,
inception branches, pyramid pooling); T_v is the paper's 10/1 conv cost
model; M_v is the activation byte size at the paper's input resolutions and
batch sizes (Table 1's Batch column), which is what makes the *relative*
memory numbers comparable to the paper's GB-scale measurements.
"""

from __future__ import annotations

from typing import Dict, List, Tuple

from repro.core.graph import Graph, Node

# (input_hw, batch) per Table 1
SETTINGS = {
    "vgg19": (224, 64),
    "resnet50": (224, 96),
    "resnet152": (224, 48),
    "densenet161": (224, 32),
    "googlenet": (224, 256),
    "unet": (572, 8),
    "pspnet": (713, 2),
}


class _B:
    """Tiny builder: nodes carry (kind, channels, hw); M_v = 4·B·C·H·W."""

    def __init__(self, batch: int):
        self.batch = batch
        self.nodes: List[Node] = []
        self.edges: List[Tuple[int, int]] = []

    def add(self, kind: str, c: int, hw: float, *preds: int) -> int:
        idx = len(self.nodes)
        mem = 4.0 * self.batch * c * hw * hw
        t = 10.0 if kind == "conv" else 1.0
        self.nodes.append(Node(idx, f"{idx}:{kind}", t, max(mem, 1.0), kind))
        for p in preds:
            self.edges.append((p, idx))
        return idx

    def cbr(self, c: int, hw: float, *preds: int) -> int:
        """conv → bn → relu (the paper's node granularity: each op a node)."""
        conv = self.add("conv", c, hw, *preds)
        bn = self.add("bn", c, hw, conv)
        return self.add("relu", c, hw, bn)

    def graph(self) -> Graph:
        return Graph(self.nodes, self.edges)


def vgg19() -> Graph:
    """16 conv + 3 FC with relu/pool interleaved → 46 nodes, pure chain."""
    b = _B(SETTINGS["vgg19"][1])
    plan = [(64, 2), (128, 2), (256, 4), (512, 4), (512, 4)]
    hw = 224
    prev = b.add("input_stem", 3, hw)
    for c, reps in plan:
        for _ in range(reps):
            conv = b.add("conv", c, hw, prev)
            prev = b.add("relu", c, hw, conv)
        hw //= 2
        prev = b.add("pool", c, hw, prev)
    for i, c in enumerate((4096, 4096, 1000)):
        fc = b.add("conv", c, 1, prev)  # FC ~ heavy
        prev = b.add("relu", c, 1, fc) if i < 2 else fc
    g = b.graph()
    return g


def _resnet(layers: Tuple[int, ...], name: str) -> Graph:
    batch = SETTINGS[name][1]
    b = _B(batch)
    hw = 56
    prev = b.add("conv", 64, 112)  # stem
    prev = b.add("pool", 64, hw, prev)
    c_in = 64
    for stage, blocks in enumerate(layers):
        c = 64 * (2**stage)
        for blk in range(blocks):
            if blk == 0 and stage > 0:
                hw //= 2
            identity = prev
            x = b.cbr(c, hw, prev)
            x = b.cbr(c, hw, x)
            x = b.add("conv", c * 4, hw, x)
            x = b.add("bn", c * 4, hw, x)
            # projection shortcut on first block of each stage
            if blk == 0:
                identity = b.add("conv", c * 4, hw, identity)
                identity = b.add("bn", c * 4, hw, identity)
            add = b.add("add", c * 4, hw, x, identity)
            prev = b.add("relu", c * 4, hw, add)
    return b.graph()


def resnet50() -> Graph:
    return _resnet((3, 4, 6, 3), "resnet50")


def resnet152() -> Graph:
    return _resnet((3, 8, 36, 3), "resnet152")


def densenet161() -> Graph:
    """Dense blocks: every layer consumes the concat of all previous ones."""
    b = _B(SETTINGS["densenet161"][1])
    hw = 56
    prev = b.add("conv", 96, 112)
    prev = b.add("pool", 96, hw, prev)
    growth = 48
    c = 96
    for stage, n_layers in enumerate((6, 12, 36, 24)):
        block_feats = [prev]
        for _ in range(n_layers):
            bn1 = b.add("bn", c, hw, *block_feats)  # reads the concat
            r1 = b.add("relu", c, hw, bn1)
            cv1 = b.add("conv", 4 * growth, hw, r1)  # 1x1
            bn2 = b.add("bn", 4 * growth, hw, cv1)
            r2 = b.add("relu", 4 * growth, hw, bn2)
            new = b.add("conv", growth, hw, r2)  # 3x3
            block_feats.append(new)
            c += growth
        if stage < 3:
            trans = b.add("conv", c // 2, hw, *block_feats)
            hw //= 2
            prev = b.add("pool", c // 2, hw, trans)
            c = c // 2
        else:
            prev = b.add("pool", c, 1, *block_feats)  # global pool
    b.add("conv", 1000, 1, prev)
    return b.graph()


def googlenet() -> Graph:
    """Inception modules: 4 parallel branches re-joined by concat."""
    b = _B(SETTINGS["googlenet"][1])
    hw = 28
    prev = b.add("conv", 64, 112)
    prev = b.add("conv", 192, 56, prev)
    prev = b.add("pool", 192, hw, prev)
    inception = [(64, 128, 32, 32), (128, 192, 96, 64), None,  # pool
                 (192, 208, 48, 64), (160, 224, 64, 64), (128, 256, 64, 64),
                 (112, 288, 64, 64), (256, 320, 128, 128), None,
                 (256, 320, 128, 128), (384, 384, 128, 128)]
    for spec in inception:
        if spec is None:
            hw //= 2
            prev = b.add("pool", 480, hw, prev)
            continue
        c1, c3, c5, cp = spec
        br1 = b.cbr(c1, hw, prev)
        br3a = b.cbr(c3 // 2, hw, prev)
        br3 = b.cbr(c3, hw, br3a)
        br5a = b.cbr(c5 // 2, hw, prev)
        br5 = b.cbr(c5, hw, br5a)
        brp_p = b.add("pool", 192, hw, prev)
        brp = b.cbr(cp, hw, brp_p)
        prev = b.add("concat", c1 + c3 + c5 + cp, hw, br1, br3, br5, brp)
    prev = b.add("pool", 1024, 1, prev)
    b.add("conv", 1000, 1, prev)
    return b.graph()


def unet() -> Graph:
    """Contracting path + expanding path with long skip connections."""
    b = _B(SETTINGS["unet"][1])
    hw = 568
    prev = None
    skips = []
    chans = (64, 128, 256, 512)
    # down
    for c in chans:
        cv = b.add("conv", c, hw, *( [prev] if prev is not None else [] ))
        prev = b.add("relu", c, hw, cv)
        cv = b.add("conv", c, hw, prev)
        prev = b.add("relu", c, hw, cv)
        skips.append(prev)
        hw //= 2
        prev = b.add("pool", c, hw, prev)
    # bottom
    cv = b.add("conv", 1024, hw, prev)
    prev = b.add("relu", 1024, hw, cv)
    cv = b.add("conv", 1024, hw, prev)
    prev = b.add("relu", 1024, hw, cv)
    # up
    for c, skip in zip(reversed(chans), reversed(skips)):
        hw *= 2
        up = b.add("conv", c, hw, prev)  # up-conv
        cat = b.add("concat", 2 * c, hw, up, skip)
        cv = b.add("conv", c, hw, cat)
        prev = b.add("relu", c, hw, cv)
        cv = b.add("conv", c, hw, prev)
        prev = b.add("relu", c, hw, cv)
    b.add("conv", 2, hw, prev)
    return b.graph()


def pspnet() -> Graph:
    """ResNet50 dilated backbone + pyramid pooling with global skips."""
    batch = SETTINGS["pspnet"][1]
    b = _B(batch)
    hw = 90  # 713/8 dilated output stride
    prev = b.add("conv", 64, 357)
    prev = b.add("pool", 64, 179, prev)
    c_in = 64
    for stage, blocks in enumerate((3, 4, 6, 3)):
        c = 64 * (2**stage)
        s_hw = 90 if stage >= 1 else 179
        for blk in range(blocks):
            identity = prev
            x = b.cbr(c, s_hw, prev)
            x = b.cbr(c, s_hw, x)
            x = b.add("conv", c * 4, s_hw, x)
            x = b.add("bn", c * 4, s_hw, x)
            if blk == 0:
                identity = b.add("conv", c * 4, s_hw, identity)
                identity = b.add("bn", c * 4, s_hw, identity)
            add = b.add("add", c * 4, s_hw, x, identity)
            prev = b.add("relu", c * 4, s_hw, add)
    backbone = prev
    # pyramid pooling: 4 scales, each pool→conv→upsample, concat with backbone
    pools = []
    for scale in (1, 2, 3, 6):
        p = b.add("pool", 2048, scale, backbone)
        cv = b.cbr(512, scale, p)
        up = b.add("upsample", 512, 90, cv)
        pools.append(up)
    cat = b.add("concat", 2048 + 4 * 512, 90, backbone, *pools)
    x = b.cbr(512, 90, cat)
    x = b.add("conv", 150, 90, x)
    b.add("upsample", 150, 713, x)
    # aux head off stage-3 (extra cross edge, as in the real PSPNet)
    return b.graph()


def executable_twin(g: Graph, batch: int = 4, width: int = 16):
    """A small *runnable* JAX twin of an abstract benchmark graph.

    Same topology, toy shapes: every node carries a ``(batch, width)`` f32
    activation; ``conv``-kind nodes apply a per-node ``(width, width)``
    ``dot_general`` (one heavy op each, mirroring the 10/1 cost model),
    every other kind a cheap elementwise ``tanh``; multi-predecessor nodes
    take the mean of their inputs.  Each node's output is tagged with the
    abstract node's *name* via ``checkpoint_name``, so a plan computed on
    the abstract graph maps directly onto the twin through
    ``save_only_these_names`` — no re-planning on the trace.  Per-node
    distinct constants keep sibling branches CSE-distinct.

    Returns ``(fwd, (params, x), byte_graph)`` where the example args are
    ``ShapeDtypeStruct``s (enough for ``jit.lower``) and ``byte_graph`` is
    the abstract topology re-priced so every node's ``M_v`` is the twin's
    actual activation byte size — the graph to evaluate analytic peaks on
    when comparing against the twin's compiled memory use.
    """
    import jax
    import jax.numpy as jnp
    from jax.ad_checkpoint import checkpoint_name

    dn = (((1,), (0,)), ((), ()))
    conv_ids = [v for v in range(g.n) if g.nodes[v].kind == "conv"]
    sinks = [v for v in range(g.n) if not g.succ[v]]

    def fwd(params, x):
        vals: Dict[int, object] = {}
        for v in range(g.n):  # builders emit nodes in topological order
            nd = g.nodes[v]
            preds = g.pred[v]
            if not preds:
                h = x * (1.0 + 0.003 * v)
            elif len(preds) == 1:
                h = vals[preds[0]]
            else:
                h = jnp.mean(jnp.stack([vals[p] for p in preds]), axis=0)
            if nd.kind == "conv":
                h = jax.lax.dot_general(h, params[str(v)], dn)
            else:
                h = jnp.tanh(h) * (1.0 + 0.003 * v)
            vals[v] = checkpoint_name(h, nd.name)
        out = 0.0
        for s in sinks:
            out = out + jnp.sum(vals[s] * vals[s])
        return out

    params = {
        str(v): jax.ShapeDtypeStruct((width, width), jnp.float32)
        for v in conv_ids
    }
    x = jax.ShapeDtypeStruct((batch, width), jnp.float32)
    nbytes = 4.0 * batch * width
    byte_nodes = [
        Node(nd.idx, nd.name, nd.time, nbytes, nd.kind) for nd in g.nodes
    ]
    return fwd, (params, x), Graph(byte_nodes, g.edges)


NETWORKS = {
    "vgg19": vgg19,
    "resnet50": resnet50,
    "resnet152": resnet152,
    "densenet161": densenet161,
    "googlenet": googlenet,
    "unet": unet,
    "pspnet": pspnet,
}

PAPER_NODE_COUNTS = {
    "pspnet": 385, "unet": 60, "resnet50": 176, "resnet152": 516,
    "vgg19": 46, "densenet161": 568, "googlenet": 134,
}
