"""Per-arch smoke tests: reduced config, one forward/train step on CPU,
shape + finiteness assertions; decode parity for representative families."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, REGISTRY, SHAPES, get_config, reduced, shape_applicable
from repro.models import build_model

RNG = jax.random.PRNGKey(0)
B, S = 2, 16


def _batch(cfg):
    batch = {
        "tokens": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(RNG, (B, S), 0, cfg.vocab_size),
    }
    if cfg.encoder_decoder:
        batch["frames"] = jax.random.normal(RNG, (B, cfg.frontend_seq, cfg.d_model))
    elif cfg.frontend != "none":
        batch["extra_embeds"] = jax.random.normal(
            RNG, (B, cfg.frontend_seq, cfg.d_model)
        )
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_train_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    loss, grads = jax.value_and_grad(model.loss)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss))
    leaves = jax.tree_util.tree_leaves(grads)
    assert leaves and all(bool(jnp.all(jnp.isfinite(l))) for l in leaves)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_logit_shape(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    batch = _batch(cfg)
    if cfg.encoder_decoder:
        enc = model.encode(params, batch["frames"])
        logits = model.decode_train(params, batch["tokens"], enc)
        assert logits.shape == (B, S, cfg.vocab_size)
    else:
        logits = model.forward(
            params, batch["tokens"], extra_embeds=batch.get("extra_embeds")
        )
        extra = cfg.frontend_seq if cfg.frontend != "none" else 0
        assert logits.shape == (B, S + extra, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    if cfg.encoder_decoder:
        frames = jax.random.normal(RNG, (B, cfg.frontend_seq, cfg.d_model))
        caches = model.init_caches(params, frames, 32)
    else:
        caches = model.init_caches(B, 32)
    logits, new_caches = model.decode_step(
        params, jnp.zeros((B, 1), jnp.int32), caches, jnp.zeros((B,), jnp.int32)
    )
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    assert jax.tree_util.tree_structure(new_caches) == jax.tree_util.tree_structure(caches)


@pytest.mark.parametrize("arch", ["stablelm-3b", "zamba2-2.7b", "xlstm-1.3b"])
def test_decode_matches_forward_teacher_forcing(arch):
    """Step-by-step decode must reproduce the full-sequence forward logits —
    the KV-cache / recurrent-state path is numerically the same model."""
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(RNG)
    T = 8
    toks = jax.random.randint(jax.random.PRNGKey(5), (1, T), 0, cfg.vocab_size)
    full = model.forward(params, toks)  # (1, T, V)
    caches = model.init_caches(1, T + 1)
    outs = []
    for t in range(T):
        logits, caches = model.decode_step(
            params, toks[:, t : t + 1], caches, jnp.array([t])
        )
        outs.append(logits[:, 0])
    stepwise = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(stepwise, np.float32),
        np.asarray(full, np.float32),
        rtol=6e-2,
        atol=6e-2,  # bf16 activations; chunked-vs-step reduction orders
    )


def test_shape_applicability_table():
    """DESIGN.md §Arch-applicability: long_500k only for ssm/hybrid."""
    long = SHAPES["long_500k"]
    runnable = sorted(
        a for a, c in REGISTRY.items() if shape_applicable(c, long)
    )
    assert runnable == ["xlstm-1.3b", "zamba2-2.7b"]
    for a, c in REGISTRY.items():
        for s in ("train_4k", "prefill_32k", "decode_32k"):
            assert shape_applicable(c, SHAPES[s])


def test_num_params_scale():
    """Analytic parameter counts are the right order of magnitude."""
    expected = {
        "xlstm-1.3b": (0.8e9, 2.5e9),
        "stablelm-3b": (2e9, 4.5e9),
        "qwen2.5-14b": (9e9, 20e9),
        "phi4-mini-3.8b": (2.5e9, 6e9),
        "mistral-large-123b": (90e9, 160e9),
        "qwen3-moe-30b-a3b": (20e9, 40e9),
        "zamba2-2.7b": (1.8e9, 4.5e9),
        "whisper-small": (0.1e9, 0.6e9),
    }
    for arch, (lo, hi) in expected.items():
        n = get_config(arch).num_params()
        assert lo <= n <= hi, (arch, n)
    moe = get_config("qwen3-moe-30b-a3b")
    assert moe.num_active_params() < 0.25 * moe.num_params()
