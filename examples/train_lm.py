"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
under a DP recomputation plan, with checkpointing and restart.

The model is a 12-layer / d=768 dense transformer (GPT-2-small class,
~124M params) on the synthetic pipeline.  The paper's technique enters as
the DP-planned ``segment_sizes`` / ``segment_remat``.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.configs.base import ModelConfig, ShapeConfig
from repro.data import DataConfig, SyntheticLM
from repro.launch.plan import plan_with_microbatching
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train import TrainConfig, Trainer


def config_100m() -> ModelConfig:
    return dataclasses.replace(
        get_config("stablelm-3b"),
        name="lm-124m",
        n_layers=12,
        d_model=768,
        n_heads=12,
        n_kv_heads=12,
        d_head=64,
        d_ff=3072,
        vocab_size=50304,
    )


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = ap.parse_args(argv)

    cfg = config_100m()
    model = build_model(cfg)
    print(f"model: {cfg.name}  params≈{cfg.num_params()/1e6:.0f}M")

    shape = ShapeConfig("cli", args.seq, args.batch, "train")
    sp, res = plan_with_microbatching(cfg, shape, dp_shards=1, model_shards=1)
    print(f"plan: {sp.n_segments} segments "
          f"(remat {sum(s for s, r in zip(sp.sizes, sp.remat) if r)}/{sum(sp.sizes)}"
          f" units), feasible={res.feasible}, "
          f"overhead={res.overhead:.0f} T units")

    params = model.init(jax.random.PRNGKey(0))
    loss_fn = lambda p, b: model.loss(p, b, segment_sizes=sp.sizes,
                                      segment_remat=sp.remat)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  global_batch=args.batch))
    tc = TrainConfig(
        total_steps=args.steps,
        ckpt_every=100,
        ckpt_dir=args.ckpt_dir,
        log_every=20,
        optimizer=AdamWConfig(lr=3e-4, warmup_steps=30, total_steps=args.steps),
    )
    tr = Trainer(loss_fn, params, tc)
    if tr.maybe_restore():
        print(f"resumed from step {tr.step}")
    out = tr.run(iter(data))
    tr.close()
    print(f"final loss {out['final_loss']:.4f} after {out['step']} steps "
          f"(skipped={out['skipped']}, stragglers={out['straggler_steps']})")


if __name__ == "__main__":
    main()
