"""Deterministic discrete-event replay: wall-clock pricing of a plan.

The DP's objective (eq. 1) is *summed overhead* — recompute seconds charged
as if every forward replay serializes with the backward pass.  On real
hardware it does not have to: while the VJP sweep of segment ``k+1`` runs,
the forward replay of segment ``k`` can stream concurrently **iff** its
buffers fit under the analytic peak.  This module prices that schedule with
a deterministic discrete-event model (no clocks, no RNG — pure folds over
the plan), so plan selection can rank candidates by *replayed wall-clock at
a memory point* instead of abstract overhead.

Event model (one backward step, segments processed last → first):

* ``forward_seconds`` — one serial forward pass over every node.
* per segment ``i``: ``recompute_seconds`` (forward replay of
  ``segments[i].recompute``), ``backward_seconds`` (the VJP sweep, priced
  ``backward_factor ×`` the segment's forward compute), ``comm_seconds``
  (collective traffic attributed to the segment, see below).
* **overlap window**: while segment ``i``'s backward runs, segment
  ``i-1``'s recompute may stream concurrently.  The window's byte headroom
  comes from ``liveness.transition_excess``'s backward-window decomposition
  — window ``i``'s live bytes are exactly
  ``M(U_{i-1}) + excess(L_{i-1}, L_i)`` and the analytic
  ``plan.peak_memory`` is the max over windows — so the replay admits at
  most ``headroom_i = peak − window_i`` bytes of early recompute.  The
  hidden time is ``min(φ·r_{i-1}, b_i + c_i)`` with
  ``φ = min(1, headroom_i / recompute_bytes_{i-1})``: recompute production
  is modeled linear in time, so a window that can hold the whole replay
  hides all of it, a zero-headroom window hides nothing, and the simulated
  peak ``max_i(window_i + min(recompute_bytes_{i-1}, headroom_i))`` stays
  ≤ the analytic peak *by construction*.

Communication is priced from the mesh: one ring all-reduce's traffic factor
``2·(n-1)/n`` over the plan's cached residuals (gradient collectives), or —
when optimized HLO text is available — the exact per-chip collective bytes
from ``analysis.hlo_text.collective_bytes``.  Bytes are attributed to
segments proportional to their kept-residual mass and divided by the
interconnect bandwidth.
"""

from __future__ import annotations

import dataclasses
from typing import List, Mapping, Optional, Sequence, Tuple

from .cost_model import OpProfile, node_seconds
from .graph import Graph, NodeSet, mask_iter, to_mask
from .liveness import transition_excess
from .schedule import ExecutionPlan
from .strategies import OFFLOAD, QUANTIZE, StrategyConfig, device_bytes

#: Mesh interconnect bandwidth used to turn collective bytes into seconds
#: (TPU-v5e ICI order of magnitude; override per call for other fabrics).
DEFAULT_INTERCONNECT_BYTES_PER_SEC: float = 4.5e10

#: VJP sweep compute relative to the segment's forward compute.  The
#: standard 1 matmul forward / 2 matmuls backward accounting; the §2 model
#: excludes backward T from *overhead*, but wall-clock must price it.
DEFAULT_BACKWARD_FACTOR: float = 2.0


@dataclasses.dataclass(frozen=True)
class SegmentTiming:
    """Replayed timings of one backward window (segment ``index``)."""

    index: int
    recompute_seconds: float  # forward replay of the uncached nodes
    backward_seconds: float  # VJP sweep (backward_factor × fwd compute)
    comm_seconds: float  # collective traffic attributed to this window
    hidden_seconds: float  # recompute of segment index-1 hidden under us
    headroom_bytes: float  # peak − this window's analytic live bytes
    #: D2H+H2D transfer plus int8 codec seconds of this segment's kept
    #: residuals under the plan's storage strategies (0 for binary plans).
    transfer_seconds: float = 0.0


@dataclasses.dataclass(frozen=True)
class ReplayResult:
    """Deterministic replay of one training step under a plan.

    ``seconds`` is the replayed step time (overlap applied when enabled),
    ``serial_seconds`` the no-overlap sum — ``seconds ≤ serial_seconds``
    always.  ``simulated_peak`` ≤ the plan's analytic peak by construction.
    """

    seconds: float
    serial_seconds: float
    forward_seconds: float
    simulated_peak: float
    overlap: bool
    segments: Tuple[SegmentTiming, ...]

    @property
    def hidden_seconds(self) -> float:
        return sum(s.hidden_seconds for s in self.segments)


def _seconds_of(g: Graph, nodes: NodeSet | Sequence[int],
                profile: Optional[OpProfile]) -> float:
    """Compute seconds of a node set: profiled rates or raw ``T_v``."""
    if profile is None:
        return sum(g.time_v[v] for v in nodes)
    return sum(node_seconds(g.nodes[v], profile) for v in nodes)


def _bytes_of(g: Graph, nodes: NodeSet | Sequence[int]) -> float:
    return sum(g.mem_v[v] for v in nodes)


def mesh_comm_bytes(plan: ExecutionPlan, g: Graph, mesh: object) -> float:
    """Ring all-reduce traffic model for one backward step on ``mesh``.

    Gradient collectives move the plan's cached residual mass once per
    step; a ring all-reduce over ``n`` devices sends ``2·(n-1)/n ×`` the
    payload per chip.  A 1-device (or absent) mesh prices to zero.
    """
    if mesh is None:
        return 0.0
    try:
        from repro.parallel.sharding import axis_sizes_of

        n = 1
        for size in axis_sizes_of(mesh).values():
            n *= size
    except Exception:
        return 0.0
    if n <= 1:
        return 0.0
    return 2.0 * (n - 1) / n * _bytes_of(g, plan.cached)


def hlo_comm_bytes(hlo_text: str) -> float:
    """Exact per-chip collective bytes from optimized HLO text."""
    from repro.analysis.hlo_text import collective_bytes

    stats = collective_bytes(hlo_text)
    return float(stats.get("total_bytes_per_chip", 0.0))


def window_peaks(g: Graph, plan: ExecutionPlan) -> List[float]:
    """Per-window analytic live bytes ``M(U_{i-1}) + excess(L_{i-1}, L_i)``.

    The backward-window decomposition behind ``dp.peak_memory_live``:
    ``max(window_peaks) == plan.peak_memory`` for any valid plan, and each
    entry bounds the bytes live while that segment's window executes.
    For strategy plans the carried mass folds each cached node at its
    strategy's device bytes (offloaded → 0, quantized → int8+scale) — the
    same ``core.strategies.device_bytes`` weights ``dp.peak_memory_live``
    uses, so the invariant holds float-for-float there too.
    """
    pins = g.store_pins_mask
    w = device_bytes(g, plan.strategy) if plan.strategy else g.mem_v
    prev_mask = 0
    m = 0.0
    peaks: List[float] = []
    for seg in plan.segments:
        mask_lp = to_mask(seg.lower_set)
        bd_mask = to_mask(g.boundary(seg.lower_set))
        peaks.append(m + transition_excess(g, prev_mask, mask_lp, bd_mask))
        # Same fold as dp.peak_memory_live's ``m + m_step`` — ascending node
        # order — so max(window_peaks) == plan.peak_memory in float, not
        # just on paper.
        cache_mask = (bd_mask | (pins & mask_lp)) & ~prev_mask
        m += sum(w[v] for v in mask_iter(cache_mask))
        prev_mask = mask_lp
    return peaks


def replay(
    g: Graph,
    plan: ExecutionPlan,
    *,
    profile: Optional[OpProfile] = None,
    backward_factor: float = DEFAULT_BACKWARD_FACTOR,
    overlap: bool = True,
    budget: Optional[float] = None,
    mesh: object = None,
    comm_bytes: Optional[float] = None,
    interconnect_bytes_per_sec: float = DEFAULT_INTERCONNECT_BYTES_PER_SEC,
    segment_costs: Optional[Mapping[int, float]] = None,
    strategies: Optional[StrategyConfig] = None,
) -> ReplayResult:
    """Price one training step of ``plan`` on ``g`` (see module docstring).

    ``profile`` converts nodes to seconds via ``cost_model.node_seconds``;
    without one, ``g.time_v`` is read directly (correct for calibrated /
    measured graphs whose ``T_v`` already are seconds-proportional).
    ``budget`` is the device memory the overlap stream may fill — defaults
    to the plan's own analytic peak, so the simulated peak never exceeds
    it; plan *selection* passes the DP budget instead, letting a
    lower-peak candidate spend its slack on early recompute (the replay
    then stays ≤ that budget).  ``segment_costs`` overrides per-segment
    *forward* seconds with compiled or profiled measurements
    (``analysis.hlo.extract_segment_costs``), keyed by segment index;
    recompute within an overridden segment is scaled by its ``T``-ratio.
    ``comm_bytes`` (e.g. from :func:`hlo_comm_bytes`) overrides the
    :func:`mesh_comm_bytes` model.

    Strategy plans (``plan.strategy`` non-empty) additionally price each
    window's kept residuals: offloaded nodes pay a D2H+H2D round trip over
    the host link and quantized nodes pay the int8 codec round trip.
    Those ``transfer_seconds`` join the window's backward/collective work —
    serial cost that the previous segment's recompute may hide under, the
    same overlap budgeting as everything else in the window.  Bandwidths
    come from ``strategies`` when given, else from the profile's
    ``host_bytes_per_sec``/``quantize_bytes_per_sec``, else the cost-model
    defaults.
    """
    segs = plan.segments
    k = len(segs)
    if comm_bytes is None:
        comm_bytes = mesh_comm_bytes(plan, g, mesh)

    # Per-segment transfer/codec seconds of the kept residuals.
    xfer_s = [0.0] * k
    if plan.strategy:
        if strategies is not None:
            off_bw = strategies.offload_bytes_per_sec
            qz_bw = strategies.quantize_bytes_per_sec
        elif profile is not None:
            off_bw = profile.host_bytes_per_sec
            qz_bw = profile.quantize_bytes_per_sec
        else:
            defaults = StrategyConfig()
            off_bw = defaults.offload_bytes_per_sec
            qz_bw = defaults.quantize_bytes_per_sec
        for i, seg in enumerate(segs):
            ob = sum(g.mem_v[v] for v in sorted(seg.keep)
                     if plan.strategy.get(v) == OFFLOAD)
            qb = sum(g.mem_v[v] for v in sorted(seg.keep)
                     if plan.strategy.get(v) == QUANTIZE)
            xfer_s[i] = 2.0 * ob / off_bw + 2.0 * qb / qz_bw

    # Per-segment forward compute seconds (and the recompute subset).
    fwd_s: List[float] = []
    rec_s: List[float] = []
    for seg in segs:
        full = _seconds_of(g, seg.nodes, profile)
        rec = _seconds_of(g, seg.recompute, profile)
        if segment_costs is not None and seg.index in segment_costs:
            measured = float(segment_costs[seg.index])
            ratio = rec / full if full > 0.0 else 0.0
            full, rec = measured, measured * ratio
        fwd_s.append(full)
        rec_s.append(rec)
    forward_seconds = sum(fwd_s)

    # Collective bytes → per-segment seconds, ∝ kept-residual mass.
    kept_mass = [_bytes_of(g, seg.keep) for seg in segs]
    total_kept = sum(kept_mass)
    comm_s = [
        (comm_bytes * km / total_kept / interconnect_bytes_per_sec
         if total_kept > 0.0 else 0.0)
        for km in kept_mass
    ]

    peaks = window_peaks(g, plan)
    peak_budget = plan.peak_memory if budget is None else max(
        budget, plan.peak_memory)

    timings: List[SegmentTiming] = []
    serial = forward_seconds
    hidden_total = 0.0
    simulated_peak = max(peaks, default=0.0)
    sim_overlap = 0.0
    for i in range(k - 1, -1, -1):
        b_i = backward_factor * fwd_s[i]
        c_i = comm_s[i]
        x_i = xfer_s[i]
        serial += rec_s[i] + b_i + c_i + x_i
        hidden = 0.0
        headroom = max(0.0, peak_budget - peaks[i])
        if overlap and i > 0 and rec_s[i - 1] > 0.0:
            rbytes = _bytes_of(g, segs[i - 1].recompute)
            phi = 1.0 if rbytes <= headroom else (
                headroom / rbytes if rbytes > 0.0 else 1.0)
            hidden = min(phi * rec_s[i - 1], b_i + c_i + x_i)
            hidden_total += hidden
            sim_overlap = max(sim_overlap, peaks[i] + min(rbytes, headroom))
        timings.append(
            SegmentTiming(
                index=segs[i].index,
                recompute_seconds=rec_s[i],
                backward_seconds=b_i,
                comm_seconds=c_i,
                hidden_seconds=hidden,
                headroom_bytes=headroom,
                transfer_seconds=x_i,
            )
        )
    timings.reverse()
    if overlap:
        simulated_peak = max(simulated_peak, sim_overlap)

    return ReplayResult(
        seconds=serial - hidden_total,
        serial_seconds=serial,
        forward_seconds=forward_seconds,
        simulated_peak=simulated_peak,
        overlap=overlap,
        segments=tuple(timings),
    )


def rank_by_replay(
    g: Graph,
    sequences: Sequence[Sequence[NodeSet]],
    *,
    assignments: Optional[Sequence[Optional[Mapping[int, str]]]] = None,
    strategies: Optional[StrategyConfig] = None,
    profile: Optional[OpProfile] = None,
    backward_factor: float = DEFAULT_BACKWARD_FACTOR,
    overlap: bool = True,
    budget: Optional[float] = None,
    mesh: object = None,
    comm_bytes: Optional[float] = None,
    segment_costs: Optional[Mapping[int, float]] = None,
) -> Tuple[int, ExecutionPlan, ReplayResult]:
    """Replay every candidate sequence; return the wall-clock winner.

    ``budget`` should be the DP budget the candidates were admitted under
    (the device memory the overlap stream may fill).  ``overlap=False``
    ranks by the serial replay — for targets that cannot run a second
    stream (a single-stream host, or profiling-only comparisons).
    ``assignments`` optionally pairs each sequence with a per-node storage
    strategy map (``None`` entries are plain binary candidates), letting
    the joint memory-strategy DP rank strategy plans and legacy all-store
    plans in one pool; ``strategies`` supplies the transfer/codec
    bandwidths pricing them.  Deterministic tie-break: (replayed seconds,
    analytic peak, index) — two candidates with identical replays resolve
    to the earlier (for sweeps: lower-overhead) one.  Returns
    ``(winner_index, plan, replay_result)``.
    """
    if not sequences:
        raise ValueError("no candidate sequences to rank")
    if assignments is not None and len(assignments) != len(sequences):
        raise ValueError("assignments must pair 1:1 with sequences")
    from .schedule import make_plan

    best: Optional[Tuple[float, float, int, ExecutionPlan, ReplayResult]] = None
    for idx, seq in enumerate(sequences):
        asg = assignments[idx] if assignments is not None else None
        plan = make_plan(
            g, list(seq), assignment=dict(asg) if asg else None,
            strategies=strategies,
        )
        res = replay(
            g, plan, profile=profile, backward_factor=backward_factor,
            overlap=overlap, budget=budget, mesh=mesh, comm_bytes=comm_bytes,
            segment_costs=segment_costs, strategies=strategies,
        )
        key = (res.seconds, plan.peak_memory, idx)
        if best is None or key < (best[0], best[1], best[2]):
            best = (res.seconds, plan.peak_memory, idx, plan, res)
    assert best is not None
    return best[2], best[3], best[4]
