from .store import AsyncCheckpointer, latest_step, restore, retain, save

__all__ = ["AsyncCheckpointer", "save", "restore", "latest_step", "retain"]
