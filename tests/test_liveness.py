"""Liveness simulator (§4.4, Appendix C) — consistency properties."""

import random

import pytest

from repro.core import exact_dp, min_feasible_budget, simulate, vanilla_peak
from repro.core.dp import peak_memory, peak_memory_live
from repro.core.graph import chain
from repro.core.lower_sets import all_lower_sets

from conftest import random_dag


def _some_plan(g, slack=1.3):
    B = min_feasible_budget(g, "exact_dp") * slack
    res = exact_dp(g, B)
    assert res.feasible
    return res


def test_liveness_never_hurts(rng):
    """Freeing at last use can only lower the peak (paper: Table 1 vs 2)."""
    for _ in range(40):
        g = random_dag(rng, rng.randint(2, 7))
        res = _some_plan(g)
        with_l = simulate(g, res.sequence, liveness=True)
        without = simulate(g, res.sequence, liveness=False)
        assert with_l.peak_memory <= without.peak_memory + 1e-9


def test_recompute_overhead_matches_eq1(rng):
    """Simulator recompute T == analytic overhead T(V \\ U_k) (eq. 1)."""
    from repro.core.dp import overhead

    for _ in range(40):
        g = random_dag(rng, rng.randint(2, 7))
        res = _some_plan(g, slack=random.Random(1).uniform(1.0, 2.0))
        sim = simulate(g, res.sequence, liveness=False)
        assert sim.recompute_overhead == pytest.approx(
            overhead(g, res.sequence)
        )


def test_vanilla_peak_upper_bounds_plans(rng):
    """A memory-constrained canonical strategy must not exceed the *plain*
    vanilla peak (no liveness).  Against the liveness-optimized vanilla,
    the paper itself observes occasional inversions (Appendix C) — so that
    stronger bound is only asserted in aggregate."""
    inversions = total = 0
    for _ in range(30):
        g = random_dag(rng, rng.randint(3, 7))
        B = min_feasible_budget(g, "exact_dp")
        res = exact_dp(g, B)
        s = simulate(g, res.sequence, liveness=True).peak_memory
        assert s <= vanilla_peak(g, liveness=False) + 1e-9
        total += 1
        if s > vanilla_peak(g, liveness=True) + 1e-9:
            inversions += 1
    assert inversions <= total // 10  # rare, as in the paper


def test_finest_sequence_recomputes_only_the_sink():
    """Singleton steps cache every boundary; on a chain only the final node
    (a sink, never in any ∂(L)) is recomputed — eq. (1)'s floor."""
    g = chain(6)
    seq = [frozenset(range(k + 1)) for k in range(6)]  # all prefixes
    sim = simulate(g, seq, liveness=False)
    assert sim.recompute_overhead == pytest.approx(g.time_v[5])


def test_memory_centric_lowers_liveness_peak_on_average(rng):
    """§4.4: maximal-overhead (MC) plans + liveness ≤ TC plans + liveness,
    on average (the paper's empirical claim — allow individual ties)."""
    wins = ties = losses = 0
    for i in range(30):
        g = random_dag(rng, 7)
        B = min_feasible_budget(g, "exact_dp") * 1.15
        tc = exact_dp(g, B, objective="time_centric")
        mc = exact_dp(g, B, objective="memory_centric")
        if not (tc.feasible and mc.feasible):
            continue
        pt = simulate(g, tc.sequence, liveness=True).peak_memory
        pm = simulate(g, mc.sequence, liveness=True).peak_memory
        if pm < pt - 1e-9:
            wins += 1
        elif pm > pt + 1e-9:
            losses += 1
        else:
            ties += 1
    assert wins + ties >= losses  # MC at least holds its own under liveness


def test_eq2_is_conservative_vs_simulator(rng):
    """The analytic peak (eq. 2) should upper-bound the no-liveness simulated
    peak on chains (where the two models coincide most closely)."""
    g = chain(8, memory=2.0)
    B = min_feasible_budget(g, "exact_dp") * 1.2
    res = exact_dp(g, B)
    sim = simulate(g, res.sequence, liveness=False)
    assert sim.peak_memory <= peak_memory(g, res.sequence) + 1e-9


# ------------------------------------------------- liveness-aware functional


def _random_increasing_sequence(rng, g, fam):
    full = frozenset(range(g.n))
    seq, cur = [], frozenset()
    while cur != full:
        cur = rng.choice([L for L in fam if cur < L])
        seq.append(cur)
    return seq


def test_analytic_liveness_peak_equals_simulator(rng):
    """Tentpole property: ``dp.peak_memory_live`` — the DP's per-transition
    memory functional (``liveness.transition_excess`` over a left-folded
    cache mass) — equals the event-level ``simulate(liveness=True)`` peak
    for *any* valid schedule, not just DP outputs.  Exact equality: costs
    are integer-valued, so both sides sum without rounding."""
    for _ in range(60):
        g = random_dag(rng, rng.randint(2, 7), p=rng.choice([0.15, 0.35, 0.6]))
        fam = [L for L in all_lower_sets(g) if L]
        for _ in range(4):
            seq = _random_increasing_sequence(rng, g, fam)
            assert peak_memory_live(g, seq) == \
                simulate(g, seq, liveness=True).peak_memory


def test_dp_results_report_the_liveness_peak(rng):
    """Every feasible DPResult's peak_memory is the simulated liveness peak
    of its schedule and fits the budget exactly (no eq.-2 slack)."""
    for _ in range(25):
        g = random_dag(rng, rng.randint(2, 7))
        B = min_feasible_budget(g, "exact_dp") * 1.3
        res = exact_dp(g, B)
        assert res.feasible
        assert res.peak_memory == peak_memory_live(g, res.sequence)
        assert res.peak_memory == \
            simulate(g, res.sequence, liveness=True).peak_memory
        assert res.peak_memory <= B


def test_liveness_functional_tightens_eq2_on_chains():
    """On chains the within-segment frees make every multi-node segment
    strictly cheaper than eq. 2's full 2·M(V') footprint: a transition over
    s chain nodes costs M(V') + 2 instead of 2·M(V') + 1 (unit memories),
    so the exact min feasible budget drops."""
    from repro.core.dp import min_feasible_budget_exact

    g = chain(16)
    fam = all_lower_sets(g)
    mfb_live = min_feasible_budget_exact(g, fam, "liveness")
    mfb_eq2 = min_feasible_budget_exact(g, fam, "eq2")
    assert mfb_live < mfb_eq2
    # and the budget is honest: the realized schedule's simulated live peak
    # is exactly the budget the DP certified
    res = exact_dp(g, mfb_live)
    assert simulate(g, res.sequence, liveness=True).peak_memory == mfb_live


def test_eq2_ablation_functional_still_available(rng):
    """functional="eq2" (Appendix C ablation / benchmarks) reproduces the
    paper's original charge: results satisfy the eq.-2 budget bound and
    report the eq.-2 peak."""
    from repro.core.dp import solve

    for _ in range(15):
        g = random_dag(rng, rng.randint(2, 6))
        fam = all_lower_sets(g)
        # the §5.1 search's upper bracket: eq.-2-feasible for any graph
        B = 2.0 * g.total_memory + max(g.mem_v)
        res = solve(g, B, fam, functional="eq2")
        assert res.feasible
        assert res.peak_memory == peak_memory(g, res.sequence)
        assert res.peak_memory <= B + 1e-9
