from .pipeline import DataConfig, SyntheticLM, global_batch_for_test

__all__ = ["DataConfig", "SyntheticLM", "global_batch_for_test"]
