"""JAX version-compat shims for the sharding/mesh surface.

The mesh API moved several times across JAX releases:

* ``jax.sharding.get_abstract_mesh`` (context abstract mesh) — newer
  releases only; older ones expose a private, incompatible variant (or
  nothing) under ``jax._src.mesh``.
* ``jax.sharding.AxisType`` — newer releases; older ones have the private
  ``jax._src.mesh.AxisTypes`` enum (with ``Auto``) or nothing at all.
* ``jax.make_mesh(..., axis_types=...)`` — the keyword only exists where
  ``AxisType`` does.
* ``jax.sharding.set_mesh`` — newer context-manager entry point; older
  releases use ``with mesh:``.

Every call site in this repo goes through the helpers below instead of
feature-testing inline, so the supported-JAX window is defined in exactly
one place.
"""

from __future__ import annotations

import contextlib
import enum
import inspect
from typing import Optional, Sequence, Tuple

import jax


class _AxisTypeFallback(enum.Enum):
    """Stand-in for ``jax.sharding.AxisType`` on JAX versions without it.

    Only the member names matter: call sites build ``(AxisType.Auto,) * n``
    tuples that ``make_mesh`` (below) silently drops when the installed JAX
    cannot accept them.
    """

    Auto = "auto"
    Explicit = "explicit"
    Manual = "manual"


AxisType = getattr(jax.sharding, "AxisType", _AxisTypeFallback)

_HAS_NATIVE_AXIS_TYPE = AxisType is not _AxisTypeFallback


def get_abstract_mesh():
    """The ambient abstract mesh, or ``None`` when unavailable.

    Returns ``None`` (never raises) when the installed JAX predates
    ``jax.sharding.get_abstract_mesh`` or when the ambient mesh is empty —
    callers treat "no mesh" and "no API" identically (replicate/no-op).
    """
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    if fn is None:
        return None
    try:
        mesh = fn()
    except Exception:
        return None
    # Guard against shape-incompatible private variants: the callers need
    # ``axis_names`` at minimum.
    if mesh is None or not hasattr(mesh, "axis_names"):
        return None
    return mesh


def _make_mesh_accepts_axis_types() -> bool:
    if not _HAS_NATIVE_AXIS_TYPE:
        return False
    try:
        return "axis_types" in inspect.signature(jax.make_mesh).parameters
    except (TypeError, ValueError):
        return False


def make_mesh(
    axis_shapes: Sequence[int],
    axis_names: Sequence[str],
    *,
    axis_types: Optional[Tuple] = None,
    devices=None,
):
    """``jax.make_mesh`` that drops ``axis_types`` on JAX versions without it."""
    kw = {}
    if devices is not None:
        kw["devices"] = devices
    if axis_types is not None and _make_mesh_accepts_axis_types():
        kw["axis_types"] = axis_types
    return jax.make_mesh(tuple(axis_shapes), tuple(axis_names), **kw)


def set_mesh(mesh):
    """Context manager installing ``mesh`` as the ambient mesh.

    Prefers ``jax.sharding.set_mesh`` (new API); falls back to the classic
    ``with mesh:`` resource-env context on older releases.
    """
    fn = getattr(jax.sharding, "set_mesh", None) or getattr(jax, "set_mesh", None)
    if fn is not None:
        # Let real errors (bad axis types, usage errors) propagate — silently
        # falling back would leave the model unsharded with no signal.
        return fn(mesh)
    if hasattr(mesh, "__enter__"):
        return mesh
    return contextlib.nullcontext(mesh)
