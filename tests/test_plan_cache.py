"""Plan compilation pipeline: digest, cache, cost model, cached planner."""

import random

import pytest

from repro.core import (
    OpProfile,
    PlanCache,
    Planner,
    exact_dp,
    min_feasible_budget,
    plan,
)
from repro.core.cost_model import (
    DEFAULT_PROFILE,
    calibrated_graph,
    load_or_profile,
    measured_times,
)
from repro.core.graph import (
    Graph,
    Node,
    canonical_maps,
    chain,
    from_cost_lists,
    graph_digest,
)

from conftest import random_dag


def permute_graph(g: Graph, perm):
    """Isomorphic copy of ``g`` with node v renamed to perm[v]."""
    nodes = [None] * g.n
    for v in range(g.n):
        old = g.nodes[v]
        nodes[perm[v]] = Node(perm[v], f"p{perm[v]}", old.time, old.memory, old.kind)
    return Graph(nodes, [(perm[a], perm[b]) for a, b in g.edges])


# ----------------------------------------------------------------- digests


def test_digest_stable_under_node_id_permutation(rng):
    for trial in range(60):
        g = random_dag(rng, rng.randint(1, 9))
        perm = list(range(g.n))
        rng.shuffle(perm)
        assert graph_digest(g) == graph_digest(permute_graph(g, perm)), trial


def test_digest_changes_with_costs_edges_kinds(rng):
    g = random_dag(rng, 6)
    d = graph_digest(g)
    # time change
    n2 = [Node(n.idx, n.name, n.time + 1.0, n.memory, n.kind) for n in g.nodes]
    assert graph_digest(Graph(n2, g.edges)) != d
    # memory change
    n3 = [Node(n.idx, n.name, n.time, n.memory * 2.0, n.kind) for n in g.nodes]
    assert graph_digest(Graph(n3, g.edges)) != d
    # kind change
    n4 = [Node(n.idx, n.name, n.time, n.memory, "conv") for n in g.nodes]
    assert graph_digest(Graph(n4, g.edges)) != d
    # edge change (drop one)
    if g.edges:
        e = sorted(g.edges)[:-1]
        assert graph_digest(Graph(list(g.nodes), e)) != d
    # names do NOT matter
    n5 = [Node(n.idx, f"renamed{n.idx}", n.time, n.memory, n.kind) for n in g.nodes]
    assert graph_digest(Graph(n5, g.edges)) == d


def test_canonical_maps_roundtrip():
    g = chain(7)
    to_pos, from_pos = canonical_maps(g)
    assert sorted(to_pos) == list(range(7))
    assert [to_pos[from_pos[i]] for i in range(7)] == list(range(7))


# ------------------------------------------------------------- cache logic


def _budget(g, slack=1.5):
    return min_feasible_budget(g, "exact_dp") * slack


def test_cache_hit_and_miss_semantics(rng):
    g = random_dag(rng, 6)
    B = _budget(g)
    c = PlanCache()
    p = Planner(cache=c)
    first = p.solve(g, B, "exact_dp")
    assert c.stats()["misses"] == 1 and c.stats()["hits"] == 0
    second = p.solve(g, B, "exact_dp")
    assert c.stats()["hits"] == 1
    assert second.sequence == first.sequence
    assert second.overhead == first.overhead
    assert second.peak_memory == first.peak_memory
    # different budget / objective / method → miss
    p.solve(g, B * 1.01, "exact_dp")
    p.solve(g, B, "exact_dp", "memory_centric")
    p.solve(g, B, "approx_dp")
    assert c.stats()["hits"] == 1 and c.stats()["misses"] == 4


def test_cached_plan_equals_fresh_solve(rng):
    """Regression: DP results identical with and without the cache."""
    for trial in range(20):
        g = random_dag(rng, rng.randint(2, 6))
        B = _budget(g, 1.0 + 0.2 * (trial % 4))
        fresh = exact_dp(g, B)
        p = Planner(cache=PlanCache())
        p.solve(g, B, "exact_dp")  # populate
        cached = p.solve(g, B, "exact_dp")  # hit
        assert cached.feasible == fresh.feasible
        if fresh.feasible:
            assert cached.sequence == fresh.sequence
            assert cached.overhead == fresh.overhead
            assert cached.peak_memory == fresh.peak_memory


def test_cache_transfers_between_isomorphic_labelings(rng):
    from repro.core.dp import overhead, peak_memory_live

    g = random_dag(rng, 6)
    perm = list(range(6))
    rng.shuffle(perm)
    g2 = permute_graph(g, perm)
    B = _budget(g)
    c = PlanCache()
    p = Planner(cache=c)
    r1 = p.solve(g, B, "exact_dp")
    r2 = p.solve(g2, B, "exact_dp")
    assert c.stats()["hits"] == 1  # digest matched, plan relabeled
    # the relabeled plan is exactly the permuted sequence, and costs agree
    assert [frozenset(perm[v] for v in L) for L in r1.sequence] == r2.sequence
    g2.check_increasing_sequence(r2.sequence)
    assert overhead(g2, r2.sequence) == pytest.approx(r1.overhead)
    assert peak_memory_live(g2, r2.sequence) <= B + 1e-9


def test_on_disk_round_trip(tmp_path, rng):
    g = random_dag(rng, 5)
    B = _budget(g)
    store = str(tmp_path / "plans")
    p1 = Planner(cache=PlanCache(cache_dir=store))
    first = p1.solve(g, B, "exact_dp")
    # fresh in-memory cache over the same store = restarted process
    c2 = PlanCache(cache_dir=store)
    p2 = Planner(cache=c2)
    again = p2.solve(g, B, "exact_dp")
    assert c2.stats()["disk_hits"] == 1
    assert again.sequence == first.sequence
    assert again.overhead == first.overhead
    assert again.peak_memory == first.peak_memory


def test_corrupt_disk_entry_degrades_to_miss(tmp_path, rng):
    import os

    g = random_dag(rng, 5)
    B = _budget(g)
    store = str(tmp_path / "plans")
    p1 = Planner(cache=PlanCache(cache_dir=store))
    p1.solve(g, B, "exact_dp")
    # truncate every stored file
    for root, _dirs, files in os.walk(store):
        for f in files:
            with open(os.path.join(root, f), "w") as fh:
                fh.write("{not json")
    c2 = PlanCache(cache_dir=store)
    res = Planner(cache=c2).solve(g, B, "exact_dp")  # re-solves, no crash
    assert res.feasible
    assert c2.stats()["disk_hits"] == 0


def test_wrong_shape_json_degrades_to_miss(tmp_path, rng):
    """Valid JSON of the wrong shape (list/scalar) must read as a miss, for
    both plan entries and aux (min-budget) entries."""
    import os

    g = random_dag(rng, 5)
    store = str(tmp_path / "plans")
    p1 = Planner(cache=PlanCache(cache_dir=store))
    rep = p1.plan(g, method="exact_dp")  # writes a plan AND an aux entry
    for root, _dirs, files in os.walk(store):
        for f in files:
            with open(os.path.join(root, f), "w") as fh:
                fh.write("[1, 2, 3]")
    p2 = Planner(cache=PlanCache(cache_dir=store))
    rep2 = p2.plan(g, method="exact_dp")  # re-solves, no crash
    assert rep2.result.sequence == rep.result.sequence
    assert rep2.budget == pytest.approx(rep.budget)


def test_unusable_cache_dir_degrades_to_memory_only(tmp_path, rng):
    """A cache store that cannot be written (path collides with a file) must
    degrade to memory-only caching, never crash planning."""
    bad = tmp_path / "store"
    bad.write_text("i am a file, not a directory")
    g = random_dag(rng, 5)
    B = _budget(g)
    c = PlanCache(cache_dir=str(bad))
    p = Planner(cache=c)
    res = p.solve(g, B, "exact_dp")
    assert res.feasible
    assert c.stats()["disk_errors"] >= 1
    # in-memory tier still works
    p.solve(g, B, "exact_dp")
    assert c.stats()["hits"] == 1


def test_cost_change_invalidates_cache(rng):
    """Changing any node cost changes the digest → cache miss, fresh solve."""
    g = random_dag(rng, 5)
    B = _budget(g)
    c = PlanCache()
    p = Planner(cache=c)
    p.solve(g, B, "exact_dp")
    bumped = Graph(
        [Node(n.idx, n.name, n.time, n.memory * 1.5, n.kind) for n in g.nodes],
        g.edges,
    )
    p.solve(bumped, B, "exact_dp")
    assert c.stats()["hits"] == 0 and c.stats()["misses"] == 2


def test_memory_functional_versions_the_cache_keys():
    """The DP's memory-functional version is hashed into every plan/sweep
    key, so entries solved under a different functional (e.g. the
    pre-liveness eq. 2) can never be served — they content-address to
    different files."""
    import repro.core.plan_cache as pc

    k = pc.PlanKey("digest", 1.0, "exact_dp", "time_centric")
    sk = pc.SweepKey("digest", "exact_dp", "time_centric")
    h, sh = k.content_hash(), sk.content_hash()
    orig = pc.MEMORY_FUNCTIONAL
    try:
        pc.MEMORY_FUNCTIONAL = "eq2-v0"  # what an old build would hash
        assert k.content_hash() != h
        assert sk.content_hash() != sh
    finally:
        pc.MEMORY_FUNCTIONAL = orig


def test_old_format_aux_entry_reads_as_miss(tmp_path):
    """Aux scalars (min budgets) from an older FORMAT_VERSION are stale by
    definition (different memory functional) and must read as misses."""
    import hashlib
    import json
    import os

    from repro.core.plan_cache import FORMAT_VERSION, PlanCache

    c = PlanCache(cache_dir=str(tmp_path))
    h = hashlib.sha256("aux|min_budget|k".encode()).hexdigest()
    path = os.path.join(str(tmp_path), "plans", h[:2], h + ".json")
    os.makedirs(os.path.dirname(path), exist_ok=True)
    with open(path, "w") as f:
        json.dump({"version": FORMAT_VERSION - 1, "value": 123.0}, f)
    assert c.get_aux("min_budget", "k") is None
    c.put_aux("min_budget", "k", 7.0)
    assert c.get_aux("min_budget", "k") == 7.0


def test_custom_family_bypasses_cache(rng):
    from repro.core.lower_sets import all_lower_sets

    g = random_dag(rng, 4)
    B = _budget(g)
    c = PlanCache()
    p = Planner(cache=c)
    fam = all_lower_sets(g)
    p.solve(g, B, "exact_dp", family=fam)
    p.solve(g, B, "exact_dp", family=fam)
    assert c.stats()["hits"] == 0 and c.stats()["misses"] == 0


def test_lru_eviction():
    c = PlanCache(capacity=2)
    gs = [chain(n) for n in (3, 4, 5)]
    p = Planner(cache=c)
    for g in gs:
        p.solve(g, 100.0, "exact_dp")
    assert c.stats()["entries_in_memory"] == 2
    # oldest evicted → miss; newest still hit
    p.solve(gs[0], 100.0, "exact_dp")
    assert c.stats()["hits"] == 0


def test_plan_front_door_cached_and_identical(rng):
    g = random_dag(rng, 5)
    r1 = plan(g, method="exact_dp")
    r2 = plan(g, method="exact_dp")
    assert r1.result.sequence == r2.result.sequence
    assert r1.result.overhead == r2.result.overhead
    assert r1.budget == r2.budget  # min-feasible budget search cached too


# ----------------------------------------------------------- cost model


def test_measured_times_prices_by_kind():
    g = from_cost_lists(
        [1e9, 1e9], [1e6, 1e6], [(0, 1)], kinds=["dot_general", "elementwise"]
    )
    prof = OpProfile(
        sec_per_flop_matmul=1e-12,
        sec_per_flop_attention=2e-12,
        sec_per_byte_elementwise=1e-9,
        backend="test",
    )
    m = measured_times(g, prof)
    assert m.time_v[0] == pytest.approx(1e9 * 1e-12)  # flops · matmul rate
    assert m.time_v[1] == pytest.approx(1e6 * 1e-9)  # bytes · HBM rate
    q = calibrated_graph(g, prof, levels=64)
    assert all(t >= 1 and float(t).is_integer() for t in q.time_v)


def test_calibration_changes_digest_and_plans_dont_alias():
    g = from_cost_lists(
        [1e9, 1e9, 1e9], [8.0, 8.0, 8.0], [(0, 1), (1, 2)],
        kinds=["dot_general"] * 3,
    )
    cal = calibrated_graph(g, DEFAULT_PROFILE, levels=32)
    assert graph_digest(cal) != graph_digest(g)


def test_load_or_profile_disk_cached(tmp_path):
    calls = []

    def fake_profiler():
        calls.append(1)
        return DEFAULT_PROFILE

    d = str(tmp_path)
    p1 = load_or_profile(cache_dir=d, profiler=fake_profiler)
    p2 = load_or_profile(cache_dir=d, profiler=fake_profiler)
    assert len(calls) == 1  # second load came from disk
    assert p1 == p2


def test_planner_with_profile_prepares_graph(rng):
    g = from_cost_lists(
        [2e9, 4e9, 2e9], [64.0, 64.0, 64.0], [(0, 1), (1, 2)],
        kinds=["dot_general"] * 3,
    )
    p = Planner(cache=PlanCache(), profile=DEFAULT_PROFILE, quantize_levels=32)
    gp = p.prepare(g)
    assert all(float(t).is_integer() for t in gp.time_v)
    B = min_feasible_budget(gp, "exact_dp") * 1.5
    res = p.solve(g, B, "exact_dp")
    assert res.feasible
    # same calibrated problem → cache hit through the calibrated digest
    p.solve(g, B, "exact_dp")
    assert p.cache.stats()["hits"] == 1


# ------------------------------------------- fleet store: lock + read-through


def test_locked_write_json_basic_and_loser_skips(tmp_path):
    from repro.core.plan_cache import _locked_write_json
    import json
    import os

    path = str(tmp_path / "e.json")
    assert _locked_write_json(path, {"v": 1}) is True
    assert json.load(open(path)) == {"v": 1}
    assert not os.path.exists(path + ".lock")  # released
    # a live lock makes the writer skip (content-addressed: same bytes)
    open(path + ".lock", "w").close()
    assert _locked_write_json(path, {"v": 2}) is False
    assert json.load(open(path)) == {"v": 1}  # untouched
    os.unlink(path + ".lock")


def test_locked_write_json_breaks_stale_lock(tmp_path):
    from repro.core.plan_cache import _locked_write_json
    import json
    import os
    import time

    path = str(tmp_path / "e.json")
    lock = path + ".lock"
    os.makedirs(os.path.dirname(path), exist_ok=True)
    open(lock, "w").close()
    old = time.time() - 3600.0  # a holder that crashed an hour ago
    os.utime(lock, (old, old))
    assert _locked_write_json(path, {"v": 3}) is True
    assert json.load(open(path)) == {"v": 3}
    assert not os.path.exists(lock)


def _race_writer(path: str, payload_v: int, n_iter: int, start_evt) -> None:
    """Module-level so multiprocessing can import it in the child."""
    from repro.core.plan_cache import _locked_write_json

    start_evt.wait()
    for _ in range(n_iter):
        _locked_write_json(path, {"v": payload_v, "pad": "x" * 4096})


def test_two_process_race_same_key(tmp_path):
    """Satellite regression (ISSUE 8): two processes hammering the same
    digest must never corrupt the entry or leave lock/tmp litter."""
    import json
    import multiprocessing as mp
    import os

    # spawn, not fork: the parent has a live (multithreaded) jax runtime
    ctx = mp.get_context("spawn")
    path = str(tmp_path / "plans" / "ab" / "abcd.json")
    start = ctx.Event()
    procs = [
        ctx.Process(target=_race_writer, args=(path, v, 200, start))
        for v in (1, 2)
    ]
    for p in procs:
        p.start()
    start.set()
    for p in procs:
        p.join(timeout=120)
        assert p.exitcode == 0
    entry = json.load(open(path))  # valid JSON, from one writer or the other
    assert entry["v"] in (1, 2) and len(entry["pad"]) == 4096
    leftovers = [f for f in os.listdir(os.path.dirname(path))
                 if f.endswith(".lock") or ".tmp." in f]
    assert leftovers == []


def test_remote_store_from_url():
    from repro.core.plan_cache import (
        SharedFSStore,
        remote_store_from_url,
    )

    assert isinstance(remote_store_from_url("/fleet/plans"), SharedFSStore)
    fs = remote_store_from_url("file:///fleet/plans")
    assert isinstance(fs, SharedFSStore) and fs.root == "/fleet/plans"
    stub = remote_store_from_url("s3://bucket/plans")
    with pytest.raises(NotImplementedError):
        stub.fetch("00" * 32)
    with pytest.raises(NotImplementedError):
        stub.push("00" * 32, {})
    with pytest.raises(ValueError):
        remote_store_from_url("ftp://nope")


def test_read_through_plan_without_local_dp(tmp_path, rng, monkeypatch):
    """ISSUE-8 acceptance: a second process with EMPTY local tiers but a
    populated fleet store serves the plan via read-through — zero local DP
    work, asserted by the miss counters and a poisoned DP entry point."""
    import repro.core.planner as planner_mod
    from repro.core.plan_cache import SharedFSStore

    g = random_dag(rng, 6)
    B = _budget(g)
    fleet = str(tmp_path / "fleet")
    # process 1: solves cold, pushes through to the fleet store
    c1 = PlanCache(remote=SharedFSStore(fleet))
    first = Planner(cache=c1).solve(g, B, "exact_dp")
    assert c1.stats()["misses"] >= 1  # it really ran the DP

    # process 2: fresh planner, fresh cache, no disk tier — remote only
    c2 = PlanCache(remote=SharedFSStore(fleet))
    p2 = Planner(cache=c2)

    def poisoned(*a, **k):  # any DP call here fails the test
        raise AssertionError("read-through path ran a local DP solve")

    monkeypatch.setattr(planner_mod, "solve", poisoned)
    monkeypatch.setattr(planner_mod.dp_mod, "min_feasible_budget_exact",
                        poisoned)
    again = p2.solve(g, B, "exact_dp")
    assert again.sequence == first.sequence
    assert again.overhead == first.overhead
    assert again.peak_memory == first.peak_memory
    st = c2.stats()
    assert st["misses"] == 0 and st["remote_hits"] == 1
    assert c2.last_tier == "remote"
    # the hit was back-filled: a repeat is a memory-tier hit
    p2.solve(g, B, "exact_dp")
    assert c2.last_tier == "memory" and c2.stats()["remote_hits"] == 1


def test_read_through_sweep_and_minbudget(tmp_path, rng, monkeypatch):
    """A cached fleet sweep answers budget queries AND min_feasible_budget
    in a cold process without any DP."""
    import repro.core.planner as planner_mod
    from repro.core.plan_cache import SharedFSStore

    g = random_dag(rng, 6)
    fleet = str(tmp_path / "fleet")
    p1 = Planner(cache=PlanCache(remote=SharedFSStore(fleet)))
    B = p1.min_feasible_budget(g, "exact_dp") * 1.5
    grid1 = p1.solve_grid(g, [B, B * 1.5], "exact_dp")  # builds + pushes sweep

    c2 = PlanCache(remote=SharedFSStore(fleet))
    p2 = Planner(cache=c2)

    def poisoned(*a, **k):
        raise AssertionError("read-through path ran a local DP")

    for name in ("solve", "exact_dp"):
        if hasattr(planner_mod, name):
            monkeypatch.setattr(planner_mod, name, poisoned)
    monkeypatch.setattr(planner_mod.dp_mod, "sweep", poisoned)
    monkeypatch.setattr(planner_mod.dp_mod, "min_feasible_budget_exact",
                        poisoned)
    assert p2.solve(g, B, "exact_dp").sequence == grid1[0].sequence
    assert c2.stats()["remote_hits"] >= 1
    assert p2.min_feasible_budget(g, "exact_dp") * 1.5 == B


def test_remote_transport_failure_degrades_to_miss(rng):
    from repro.core.plan_cache import RemoteStore

    class Broken(RemoteStore):
        def fetch(self, h):
            raise OSError("transport down")

        def push(self, h, entry):
            raise OSError("transport down")

    g = random_dag(rng, 5)
    B = _budget(g)
    c = PlanCache(remote=Broken())
    p = Planner(cache=c)
    res = p.solve(g, B, "exact_dp")  # fetch+push both fail — still plans
    assert res.feasible
    assert c.stats()["remote_errors"] >= 2
    p.solve(g, B, "exact_dp")
    assert c.stats()["hits"] == 1  # local tiers unaffected


def test_last_tier_provenance(tmp_path, rng):
    g = random_dag(rng, 5)
    B = _budget(g)
    store = str(tmp_path / "plans")
    c1 = PlanCache(cache_dir=store)
    p1 = Planner(cache=c1)
    p1.solve(g, B, "exact_dp")
    assert c1.last_tier is None  # miss → solved fresh
    p1.solve(g, B, "exact_dp")
    assert c1.last_tier == "memory"
    c2 = PlanCache(cache_dir=store)  # restarted process
    Planner(cache=c2).solve(g, B, "exact_dp")
    assert c2.last_tier == "disk"


def test_default_remote_store_attach_detach():
    from repro.core.plan_cache import (
        SharedFSStore,
        default_cache,
        set_default_remote_store,
    )

    try:
        c = set_default_remote_store("/tmp/fleet-xyz")
        assert c is default_cache()
        assert isinstance(c.remote, SharedFSStore)
    finally:
        set_default_remote_store(None)
    assert default_cache().remote is None


# ------------------------------------------------------------------ prewarm


def test_prewarm_builds_then_reports_warm(rng):
    g = random_dag(rng, 6)
    p = Planner(cache=PlanCache())
    assert p.prewarm(g, "exact_dp") is False  # cold: built the sweep
    assert p.prewarm(g, "exact_dp") is True  # now hot
    # every later budget query is a frontier lookup — no new cache misses
    misses = p.cache.stats()["misses"]
    B = p.min_feasible_budget(g, "exact_dp")
    res = p.solve(g, B * 1.3, "exact_dp")
    assert res.feasible
    assert p.cache.stats()["misses"] == misses


def test_prewarm_reads_through_fleet_store(tmp_path, rng, monkeypatch):
    """Replica #2's pre-warm is a read-through of replica #1's pushed sweep
    — no DP in the second process."""
    import repro.core.planner as planner_mod
    from repro.core.plan_cache import SharedFSStore

    g = random_dag(rng, 6)
    fleet = str(tmp_path / "fleet")
    assert Planner(cache=PlanCache(remote=SharedFSStore(fleet))).prewarm(
        g, "exact_dp") is False

    p2 = Planner(cache=PlanCache(remote=SharedFSStore(fleet)))

    def poisoned(*a, **k):
        raise AssertionError("prewarm read-through ran a local DP")

    monkeypatch.setattr(planner_mod.dp_mod, "sweep", poisoned)
    assert p2.prewarm(g, "exact_dp") is True


# ------------------------------------------------------- store GC (ISSUE 9)


def _fill_store(store, n=8, pad=40):
    for i in range(n):
        store.push(f"{i:02x}" + "a" * 62, {"v": i, "pad": "x" * pad})


def test_shared_fs_store_gc_size_bound(tmp_path):
    import os

    from repro.core.plan_cache import SharedFSStore

    store = SharedFSStore(str(tmp_path), max_bytes=200)
    _fill_store(store, n=8)
    stats = store.gc()
    assert stats["bytes"] <= 200
    assert stats["removed"] >= 1
    assert stats["bytes_freed"] > 0
    # newest entries survive, oldest were evicted
    survivors = {
        f for _, d, fs in os.walk(tmp_path) for f in fs
        if f.endswith(".json")
    }
    assert ("07" + "a" * 62 + ".json") in survivors
    # no lock litter after the sweep
    locks = [f for _, d, fs in os.walk(tmp_path) for f in fs
             if f.endswith(".lock")]
    assert locks == []


def test_shared_fs_store_gc_age_bound(tmp_path):
    import time

    from repro.core.plan_cache import SharedFSStore

    store = SharedFSStore(str(tmp_path), max_age_s=3600.0)
    _fill_store(store, n=4)
    assert store.gc()["removed"] == 0  # everything is fresh
    # pretend an hour passed
    stats = store.gc(now=time.time() + 3601.0)
    assert stats["removed"] == 4 and stats["bytes"] == 0


def test_shared_fs_store_gc_skips_locked_entries(tmp_path):
    import os
    import time

    from repro.core.plan_cache import SharedFSStore

    store = SharedFSStore(str(tmp_path))
    h = "ab" + "c" * 62
    store.push(h, {"v": 1})
    path = store._path(h)
    open(path + ".lock", "w").close()  # a live writer owns this digest
    bounded = SharedFSStore(str(tmp_path), max_age_s=0.0)
    time.sleep(0.02)
    stats = bounded.gc()
    assert os.path.exists(path)  # refreshing entry survived the sweep
    assert stats["removed"] == 0
    os.unlink(path + ".lock")
    assert bounded.gc()["removed"] == 1


def test_shared_fs_store_gc_triggers_on_push(tmp_path):
    from repro.core.plan_cache import SharedFSStore

    store = SharedFSStore(str(tmp_path), max_bytes=150, gc_every=4)
    _fill_store(store, n=8)  # 8 pushes → 2 opportunistic sweeps
    assert store.gc()["bytes"] <= 150
    # an unbounded store never sweeps on push (gc() stays a manual call)
    unbounded = SharedFSStore(str(tmp_path))
    _fill_store(unbounded, n=4)
    assert unbounded.gc(now=0.0)["removed"] == 0  # no bounds → no rule fires


def test_gc_evicted_plan_is_resolvable(tmp_path, rng):
    """Eviction costs a re-solve, never a wrong plan: after a full sweep the
    same planner query re-solves and re-pushes."""
    from repro.core.plan_cache import SharedFSStore

    g = random_dag(rng, 6)
    fleet = str(tmp_path / "fleet")
    store = SharedFSStore(fleet, max_age_s=0.0)
    p1 = Planner(cache=PlanCache(remote=store))
    B = p1.min_feasible_budget(g, "exact_dp")
    res1 = p1.solve(g, B, "exact_dp")
    import time

    time.sleep(0.02)
    store.gc()  # everything evicted
    p2 = Planner(cache=PlanCache(remote=SharedFSStore(fleet)))
    res2 = p2.solve(g, B, "exact_dp")
    assert res2.sequence == res1.sequence
    assert res2.overhead == res1.overhead


# ------------------------------------------- pluggable transports (ISSUE 9)


def test_callable_store_roundtrip_and_none_normalization():
    from repro.core.plan_cache import CallableStore

    blob = {}
    store = CallableStore(fetch=blob.get,
                          push=lambda h, e: blob.__setitem__(h, e),
                          scheme="mem")
    store.push("aa", {"k": 1})
    assert store.fetch("aa") == {"k": 1}
    assert store.fetch("missing") is None
    # non-dict fetch results normalize to a miss
    blob["bad"] = "not-a-dict"
    assert store.fetch("bad") is None


def test_register_transport_routes_bucket_urls(rng):
    from repro.core.plan_cache import (
        CallableStore,
        _TRANSPORTS,
        register_transport,
        remote_store_from_url,
    )

    blob = {}
    register_transport("s3", lambda url: CallableStore(
        fetch=blob.get,
        push=lambda h, e: blob.__setitem__(h, e),
        scheme="s3"))
    try:
        store = remote_store_from_url("s3://bucket/plans")
        assert store.scheme == "s3"
        # the full cache pipeline pushes through and reads through it
        g = random_dag(rng, 5)
        c1 = PlanCache(remote="s3://bucket/plans")
        p1 = Planner(cache=c1)
        B = p1.min_feasible_budget(g, "exact_dp")
        res1 = p1.solve(g, B, "exact_dp")
        assert blob  # pushed through the registered transport
        c2 = PlanCache(remote="s3://bucket/plans")
        res2 = Planner(cache=c2).solve(g, B, "exact_dp")
        assert res2.sequence == res1.sequence
        assert c2.stats()["remote_hits"] >= 1
    finally:
        del _TRANSPORTS["s3"]
    # unregistered again: back to the stub
    with pytest.raises(NotImplementedError, match="register_transport"):
        remote_store_from_url("s3://bucket/plans").fetch("00" * 32)


def test_transport_exceptions_degrade_to_miss(rng):
    from repro.core.plan_cache import CallableStore

    def boom(*a):
        raise OSError("transport down")

    c = PlanCache(remote=CallableStore(fetch=boom, push=boom))
    g = random_dag(rng, 5)
    p = Planner(cache=c)
    res = p.solve(g, p.min_feasible_budget(g, "exact_dp"), "exact_dp")
    assert res.feasible  # planning never fails on a broken transport
    assert c.stats()["remote_errors"] >= 1
