"""Gradient compression for cross-pod data parallelism (beyond-paper).

At 2+ pods the gradient all-reduce crosses the inter-pod links (DCI), which
are an order of magnitude slower than intra-pod ICI.  Standard mitigation:
hierarchical reduce (reduce-scatter intra-pod → compressed all-reduce across
pods → all-gather intra-pod) with int8 block-quantized payloads and error
feedback so the quantization noise is re-injected next step instead of lost.

Two entry points:

* ``compress / decompress`` — block-wise symmetric int8 quantization
  (per-256-element scales), used by the train step's error-feedback hook.
* ``hierarchical_psum`` — a shard_map-compatible collective: reduce-scatter
  over the intra-pod "data" axis, int8 all-reduce over "pod", all-gather
  back; falls back to a plain psum when the mesh has no "pod" axis.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


BLOCK = 256


class Compressed(NamedTuple):
    q: jax.Array  # int8 payload
    scale: jax.Array  # f32 per-block scales
    shape: Tuple[int, ...]


def compress(x: jax.Array) -> Compressed:
    shape = x.shape
    flat = x.astype(jnp.float32).reshape(-1)
    pad = (-flat.size) % BLOCK
    flat = jnp.pad(flat, (0, pad))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-12)
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return Compressed(q=q, scale=scale[:, 0], shape=shape)


def decompress(c: Compressed) -> jax.Array:
    blocks = c.q.astype(jnp.float32) * c.scale[:, None]
    flat = blocks.reshape(-1)
    n = 1
    for d in c.shape:
        n *= d
    return flat[:n].reshape(c.shape)


def straight_through_roundtrip(x: jax.Array) -> jax.Array:
    """int8 round-trip with a straight-through gradient.

    Value is ``decompress(compress(x))`` (the int8+scale storage a
    ``quantize`` plan strategy keeps on device); gradient is identity —
    ``round``/``clip`` have zero derivative, so without the estimator the
    cotangent through a quantized residual would vanish.
    """
    rt = decompress(compress(jax.lax.stop_gradient(x))).astype(x.dtype)
    return x + jax.lax.stop_gradient(rt - x)


def quantize_roundtrip_with_feedback(
    grads: Any, error: Any
) -> Tuple[Any, Any]:
    """Error-feedback int8 round-trip: g' = Q(g + e);  e' = (g + e) - g'.

    Numerically this is exactly what the compressed cross-pod all-reduce
    applies to each shard; running it inside the train step keeps single-host
    tests bit-faithful to the multi-pod deployment.
    """

    def one(g, e):
        target = g.astype(jnp.float32) + e
        q = decompress(compress(target))
        return q.astype(g.dtype), target - q

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(treedef, [o[0] for o in outs])
    new_e = jax.tree_util.tree_unflatten(treedef, [o[1] for o in outs])
    return new_g, new_e


def init_error_feedback(grads_shape: Any) -> Any:
    return jax.tree_util.tree_map(
        lambda g: jnp.zeros(g.shape, jnp.float32), grads_shape
    )


def hierarchical_psum(x: jax.Array, data_axis: str = "data", pod_axis: str = "pod"):
    """shard_map collective: reduce-scatter(data) → int8 psum(pod) → all-gather.

    Use inside ``shard_map``; reduces cross-pod bytes by 4× (int8 vs f32)
    at the cost of block-quantization noise (bounded by error feedback at the
    caller).  Falls back to plain psum if no pod axis is bound.
    """
    try:
        pod_size = jax.lax.axis_size(pod_axis)
    except NameError:
        pod_size = 1
    if pod_size == 1:
        return jax.lax.psum(x, data_axis)
    # intra-pod reduce-scatter over leading dim
    xs = jax.lax.psum_scatter(x, data_axis, scatter_dimension=0, tiled=True)
    c = compress(xs)
    qsum = jax.lax.psum(c.q.astype(jnp.int32), pod_axis)
    ssum = jax.lax.psum(c.scale, pod_axis)  # conservative shared scale path
    xs = (qsum.astype(jnp.float32) * (ssum / pod_size)[:, None]).reshape(c.q.shape[0] * BLOCK)[
        : xs.size
    ].reshape(xs.shape)
    return jax.lax.all_gather(xs, data_axis, axis=0, tiled=True)
