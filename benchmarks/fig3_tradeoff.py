"""Figure 3 — batch-size vs runtime trade-off.

For each network and batch multiplier we scale every M_v linearly (activation
memory ∝ batch), fix the device budget at the paper's 11.4 GB K40c, and ask
each method for a plan.  Runtime proxy = T(V) + overhead in the paper's T
units (1 forward = T(V)); vanilla runs only while its simulated peak fits,
after which its line is the dashed extrapolation (slope = batch).

The paper's headline numbers this reproduces qualitatively:
* recomputation methods extend the max batch far beyond vanilla (PSPNet 2→8);
* DP-TC beats Chen on runtime at equal batch (ResNet152 ≈ 1.16×).

Scaling every M_v by the batch multiplier at a fixed device budget is the
same problem as the *base* graph at budget ``DEVICE_GB / mult`` (eq. 2 is
linear in M), so the whole DP column of this figure is ONE budget grid per
objective — served by ``Planner.solve_grid`` from a single capped sweep
(core.dp.sweep), cached under the budget-free ``sweep`` entry kind.
Re-running the figure, or sharing a cache dir with other jobs, pays for no
DP at all.
"""

from __future__ import annotations

from typing import Dict, List

from repro.core import chen_sqrt_n, get_default_planner, simulate, vanilla_peak
from repro.core.graph import Graph, Node

from .networks import NETWORKS, SETTINGS

DEVICE_GB = 11.4e9  # K40c


def scale_graph(g: Graph, factor: float) -> Graph:
    nodes = [
        Node(n.idx, n.name, n.time, n.memory * factor, n.kind) for n in g.nodes
    ]
    return Graph(nodes, g.edges)


def run_network(name: str, multipliers=(1, 2, 3, 4)) -> List[Dict]:
    base = NETWORKS[name]()
    planner = get_default_planner()
    # the whole batch sweep is one budget grid on the base graph: one capped
    # sweep per objective answers every multiplier (bit-identical to solving
    # each budget separately), and the sweep itself is cached
    budgets = [DEVICE_GB / mult for mult in multipliers]
    grids = {
        key: planner.solve_grid(base, budgets, "approx_dp", obj)
        for obj, key in (("time_centric", "dp_tc"), ("memory_centric", "dp_mc"))
    }
    rows = []
    for k, mult in enumerate(multipliers):
        g = scale_graph(base, mult)
        fwd_T = g.total_time
        row: Dict = {"network": name, "batch_mult": mult, "fwd_T": fwd_T}
        # vanilla: feasible iff its simulated peak fits the device
        van = vanilla_peak(g, liveness=True)
        row["vanilla"] = 1.0 if van <= DEVICE_GB else None  # relative runtime
        row["vanilla_peak"] = van
        # chen
        chen = chen_sqrt_n(g)
        pk = simulate(g, chen.sequence, liveness=True).peak_memory
        row["chen"] = (
            (fwd_T + chen.overhead) / fwd_T if pk <= DEVICE_GB else None
        )
        for key in ("dp_tc", "dp_mc"):
            res = grids[key][k]
            if res.feasible:
                pk = simulate(g, res.sequence, liveness=True).peak_memory
                row[key] = (fwd_T + res.overhead) / fwd_T if pk <= DEVICE_GB else None
            else:
                row[key] = None
        rows.append(row)
    return rows


def main(nets=("resnet152", "pspnet", "unet", "googlenet")) -> List[Dict]:
    print("\n== Figure 3 — relative runtime (fwd+overhead)/fwd vs batch ==")
    print(f"{'network':12s} {'batch x':>8s} {'vanilla':>8s} {'chen':>8s} "
          f"{'DP-TC':>8s} {'DP-MC':>8s}")
    all_rows = []
    for name in nets:
        for row in run_network(name):
            fmt = lambda v: f"{v:8.3f}" if v is not None else f"{'OOM':>8s}"
            print(f"{name:12s} {row['batch_mult']:>8d} {fmt(row['vanilla'])} "
                  f"{fmt(row['chen'])} {fmt(row['dp_tc'])} {fmt(row['dp_mc'])}")
            all_rows.append(row)
    # headline claims
    for name in nets:
        rows = [r for r in all_rows if r["network"] == name]
        van_max = max((r["batch_mult"] for r in rows if r["vanilla"]), default=0)
        dp_max = max((r["batch_mult"] for r in rows if r["dp_mc"] or r["dp_tc"]), default=0)
        both = [r for r in rows if r["chen"] and r["dp_tc"]]
        if both:
            r = both[-1]
            print(f"  {name}: max batch vanilla×{van_max} → DP×{dp_max}; "
                  f"at ×{r['batch_mult']} DP-TC/Chen runtime = "
                  f"{r['dp_tc']/r['chen']:.3f}")
    return all_rows


if __name__ == "__main__":
    main()
