"""Docs stay truthful: snippets execute, links and path references resolve.

Every fenced ```python block in README.md and docs/*.md runs against the
current API (each block in a fresh namespace), every relative markdown
link resolves to a real file, and every `path`-looking reference to
src/ / docs/ / benchmarks/ / tests/ / examples/ exists.  Wired into CI as
its own step so a stale doc fails the build with a readable message.
"""

import os
import re

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

DOC_FILES = ["README.md"] + sorted(
    os.path.join("docs", f)
    for f in os.listdir(os.path.join(REPO, "docs"))
    if f.endswith(".md")
)

_FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
_LINK = re.compile(r"\[[^\]]*\]\(([^)#\s]+)(?:#[^)]*)?\)")
_PATHREF = re.compile(
    r"`((?:src|docs|benchmarks|tests|examples)/[A-Za-z0-9_./-]+)`"
)


def _read(relpath: str) -> str:
    with open(os.path.join(REPO, relpath), encoding="utf-8") as f:
        return f.read()


def _snippets():
    for relpath in DOC_FILES:
        for i, m in enumerate(_FENCE.finditer(_read(relpath))):
            code = m.group(1)
            if code.lstrip().startswith("# sketch"):
                continue  # illustrative fragment, marked non-runnable
            yield pytest.param(relpath, code, id=f"{relpath}#{i}")


@pytest.mark.parametrize("relpath,code", _snippets())
def test_doc_snippet_executes(relpath, code):
    """Each fenced python block is a self-contained runnable example."""
    exec(compile(code, f"<{relpath}>", "exec"), {"__name__": "__docs__"})


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_doc_links_resolve(relpath):
    """Relative markdown links point at files that exist."""
    base = os.path.dirname(os.path.join(REPO, relpath))
    missing = []
    for target in _LINK.findall(_read(relpath)):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, target))):
            missing.append(target)
    assert not missing, f"{relpath}: dead links {missing}"


@pytest.mark.parametrize("relpath", DOC_FILES)
def test_doc_path_references_exist(relpath):
    """`src/...`-style inline code references name real files/dirs."""
    missing = []
    for ref in _PATHREF.findall(_read(relpath)):
        if not os.path.exists(os.path.join(REPO, ref)):
            missing.append(ref)
    assert not missing, f"{relpath}: stale path references {missing}"


def test_readme_and_docs_exist():
    for f in ("README.md", "docs/architecture.md", "docs/plan_cache.md"):
        assert os.path.exists(os.path.join(REPO, f)), f
