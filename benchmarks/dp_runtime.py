"""§5.1 planner-runtime comparison: exact vs approximate DP wall time.

Paper: "The exact DP algorithm required more than 80 secs to complete for
GoogLeNet and PSPNet, while the approximate DP completed within 1 sec for
all networks."  Our pure-Python implementation shifts the absolute scale but
must reproduce the ordering and the #𝓛-driven blow-up.
"""

from __future__ import annotations

import time
from typing import Dict

from repro.core import approx_dp, exact_dp, min_feasible_budget
from repro.core.lower_sets import all_lower_sets, count_lower_sets, pruned_lower_sets

from .networks import NETWORKS

EXACT_BUDGET_S = 120.0  # per-network cap on the exact solve


def main() -> Dict[str, Dict]:
    print("\n== DP runtime: exact vs approximate (§5.1) ==")
    print(f"{'network':12s} {'#V':>5s} {'#L_G':>8s} {'approx_s':>9s} "
          f"{'exact_s':>9s} {'approx_oh':>10s} {'exact_oh':>9s}")
    out = {}
    for name, f in NETWORKS.items():
        g = f()
        fam_p = pruned_lower_sets(g)
        B = min_feasible_budget(g, family=fam_p, tol=1e-2) * 1.05
        t0 = time.perf_counter()
        ap = approx_dp(g, B)
        t_ap = time.perf_counter() - t0
        try:
            nL = count_lower_sets(g, limit=200_000)
        except RuntimeError:
            nL = -1
        # exact solve with a wall-clock budget (the paper also reports
        # exact-DP blow-ups rather than waiting them out)
        t_ex = None
        ex_oh = None
        if 0 < nL <= 2_000:
            fam_e = all_lower_sets(g)
            t0 = time.perf_counter()
            ex = exact_dp(g, B)
            t_ex = time.perf_counter() - t0
            ex_oh = ex.overhead if ex.feasible else float("nan")
        row = {
            "n": g.n, "num_lower_sets": nL, "approx_s": t_ap, "exact_s": t_ex,
            "approx_overhead": ap.overhead if ap.feasible else None,
            "exact_overhead": ex_oh,
        }
        out[name] = row
        print(f"{name:12s} {g.n:>5d} {nL:>8d} {t_ap:>9.2f} "
              f"{t_ex if t_ex is not None else float('nan'):>9.2f} "
              f"{row['approx_overhead'] or float('nan'):>10.0f} "
              f"{ex_oh if ex_oh is not None else float('nan'):>9.0f}")
    # paper's qualitative claim: approx ≈ exact in quality where both ran
    both = [(r["approx_overhead"], r["exact_overhead"]) for r in out.values()
            if r["exact_overhead"] is not None and r["approx_overhead"] is not None]
    if both:
        ratios = [a / e for a, e in both if e]
        print(f"  approx/exact overhead ratio: "
              f"min {min(ratios):.2f} max {max(ratios):.2f} "
              f"(paper: 'did not differ much')")
    return out


if __name__ == "__main__":
    main()
