"""Flash attention as Pallas TPU kernels (forward + recompute backward).

This is the kernel-level instance of the paper's idea: the (Sq, Sk) score
matrix is *never cached* — the forward keeps only the per-row logsumexp
(M_v of the boundary, in the paper's language), and the backward *recomputes*
the probabilities blockwise from q, k and that statistic.  Cache O(S) instead
of O(S²); recompute cost is one extra QKᵀ per backward block — exactly the
overhead-vs-memory trade the DP reasons about, hard-coded at the tile level.

TPU adaptation (DESIGN.md §3): tiles are BlockSpec-shaped for VMEM residency
with MXU-aligned (multiple-of-128) matmul dims; the kv loop is the innermost
*sequential* grid dimension carrying the online-softmax state in VMEM scratch
(TPU grids iterate sequentially per core, unlike CUDA thread blocks, so the
accumulator lives across grid steps instead of in shared memory).

Layouts: q (B, H, Sq, D);  k, v (B, KV, Sk, D) with KV | H (GQA: the kv-head
index map is h → h·KV/H).  All matmuls accumulate in f32.

Validated in interpret mode against kernels.ref on CPU; on TPU the same
pallas_call lowers to Mosaic.
"""

from __future__ import annotations

import functools
import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

try:  # TPU scratch memory spaces; interpret mode accepts them on CPU too
    from jax.experimental.pallas import tpu as pltpu

    _VMEM = pltpu.VMEM
except Exception:  # pragma: no cover - very old jax
    _VMEM = None

NEG_INF = -1e30  # large-negative instead of -inf: keeps masked exp() exact 0
                 # without nan from (-inf) - (-inf)

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _fwd_kernel(
    q_ref,  # (1, 1, bq, D)
    k_ref,  # (1, 1, bk, D)
    v_ref,  # (1, 1, bk, D)
    o_ref,  # (1, 1, bq, D)
    lse_ref,  # (1, 1, bq)
    acc_ref,  # scratch (bq, D) f32
    m_ref,  # scratch (bq, 128) f32
    l_ref,  # scratch (bq, 128) f32
    *,
    causal: bool,
    sm_scale: float,
    block_q: int,
    block_k: int,
    seq_k: int,
    seq_q: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # causal: skip blocks strictly above the diagonal band
    # query rows of this block: [iq·bq, iq·bq + bq); keys: [ik·bk, ik·bk + bk)
    off = seq_k - seq_q  # decode-style alignment (query i sees keys ≤ i+off)
    run = (not causal) or (ik * block_k <= iq * block_q + block_q - 1 + off)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)  # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)  # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale  # (bq, bk)
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos + off >= kpos, s, NEG_INF)

        m_prev = m_ref[:, 0]  # (bq,)
        m_cur = jnp.max(s, axis=-1)  # (bq,)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)  # (bq,)
        p = jnp.exp(s - m_new[:, None])  # (bq, bk)
        l_new = l_ref[:, 0] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new[:, None], m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new[:, None], l_ref.shape)

    @pl.when(ik == nk - 1)
    def _finalize():
        l = l_ref[:, 0]
        l_safe = jnp.where(l > 0.0, l, 1.0)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)
        m = m_ref[:, 0]
        lse = jnp.where(l > 0.0, m + jnp.log(l_safe), NEG_INF)
        lse_ref[0, 0] = lse.astype(lse_ref.dtype)


def flash_attention_fwd(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, KV, Sk, D)
    v: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Returns (out (B,H,Sq,D), lse (B,H,Sq))."""
    B, H, Sq, D = q.shape
    KV, Sk = k.shape[1], k.shape[2]
    assert H % KV == 0, (H, KV)
    group = H // KV
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    assert Sq % block_q == 0 and Sk % block_k == 0, (Sq, block_q, Sk, block_k)
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / math.sqrt(D)

    kernel = functools.partial(
        _fwd_kernel,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
        seq_k=Sk,
        seq_q=Sq,
    )
    grid = (B, H, nq, nk)
    scratch = [
        _VMEM((block_q, D), jnp.float32),
        _VMEM((block_q, 128), jnp.float32),
        _VMEM((block_q, 128), jnp.float32),
    ]
    out, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_k, D), lambda b, h, iq, ik, g=group: (b, h // g, ik, 0)
            ),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
            jax.ShapeDtypeStruct((B, H, Sq), jnp.float32),
        ],
        scratch_shapes=scratch,
        interpret=interpret,
    )(q, k, v)
    return out, lse


# ---------------------------------------------------------------------------
# Backward — recompute probabilities blockwise from (q, k, lse)
# ---------------------------------------------------------------------------


def _bwd_dq_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, dq_acc_ref,
    *, causal, sm_scale, block_q, block_k, seq_k, seq_q
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        dq_acc_ref[...] = jnp.zeros_like(dq_acc_ref)

    off = seq_k - seq_q
    run = (not causal) or (ik * block_k <= iq * block_q + block_q - 1 + off)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]  # (bq,)
        delta = delta_ref[0, 0]  # (bq,) rowsum(do * o)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos + off >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # recomputed probabilities
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )  # (bq, bk)
        ds = p * (dp - delta[:, None]) * sm_scale
        dq_acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(ik == nk - 1)
    def _finalize():
        dq_ref[0, 0] = dq_acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(
    q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dk_ref, dv_ref,
    dk_acc_ref, dv_acc_ref,
    *, causal, sm_scale, block_q, block_k, seq_k, seq_q
):
    ik = pl.program_id(2)
    iq = pl.program_id(3)
    nq = pl.num_programs(3)

    @pl.when(iq == 0)
    def _init():
        dk_acc_ref[...] = jnp.zeros_like(dk_acc_ref)
        dv_acc_ref[...] = jnp.zeros_like(dv_acc_ref)

    off = seq_k - seq_q
    run = (not causal) or (ik * block_k <= iq * block_q + block_q - 1 + off)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)
        k = k_ref[0, 0].astype(jnp.float32)
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale
        if causal:
            qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
            kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
            s = jnp.where(qpos + off >= kpos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk) recomputed
        dv_acc_ref[...] += jax.lax.dot_general(
            p, do, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # pᵀ · do  (bk, D)
        dp = jax.lax.dot_general(
            do, v, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        )
        ds = p * (dp - delta[:, None]) * sm_scale  # (bq, bk)
        dk_acc_ref[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )  # dsᵀ · q  (bk, D)

    @pl.when(iq == nq - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc_ref[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc_ref[...].astype(dv_ref.dtype)


def flash_attention_bwd(
    q: jax.Array,  # (B, H, Sq, D)
    k: jax.Array,  # (B, H, Sk, D)  — pre-expanded to full heads
    v: jax.Array,
    out: jax.Array,
    lse: jax.Array,  # (B, H, Sq)
    do: jax.Array,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    B, H, Sq, D = q.shape
    Sk = k.shape[2]
    assert k.shape[1] == H, "backward expects kv expanded to full heads"
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq, nk = Sq // block_q, Sk // block_k
    sm_scale = 1.0 / math.sqrt(D)
    delta = jnp.sum(
        do.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    )  # (B, H, Sq)

    kw = dict(
        causal=causal, sm_scale=sm_scale, block_q=block_q, block_k=block_k,
        seq_k=Sk, seq_q=Sq,
    )

    q_spec_q = pl.BlockSpec((1, 1, block_q, D), lambda b, h, iq, ik: (b, h, iq, 0))
    k_spec_q = pl.BlockSpec((1, 1, block_k, D), lambda b, h, iq, ik: (b, h, ik, 0))
    r_spec_q = pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, **kw),
        grid=(B, H, nq, nk),
        in_specs=[q_spec_q, k_spec_q, k_spec_q, q_spec_q, r_spec_q, r_spec_q],
        out_specs=[q_spec_q],
        out_shape=[jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype)],
        scratch_shapes=[_VMEM((block_q, D), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, lse, delta)[0]

    # dk/dv: kv block is the carried tile; q blocks iterate innermost
    q_spec_k = pl.BlockSpec((1, 1, block_q, D), lambda b, h, ik, iq: (b, h, iq, 0))
    k_spec_k = pl.BlockSpec((1, 1, block_k, D), lambda b, h, ik, iq: (b, h, ik, 0))
    r_spec_k = pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq))

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, **kw),
        grid=(B, H, nk, nq),
        in_specs=[q_spec_k, k_spec_k, k_spec_k, q_spec_k, r_spec_k, r_spec_k],
        out_specs=[k_spec_k, k_spec_k],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, Sk, D), k.dtype),
            jax.ShapeDtypeStruct((B, H, Sk, D), v.dtype),
        ],
        scratch_shapes=[
            _VMEM((block_k, D), jnp.float32),
            _VMEM((block_k, D), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, do, lse, delta)
    return dq, dk, dv
