"""Sharded planning: per-device M_v, per-device budgets, pjit-composable twins.

Most assertions need only the *accounting* — sharding-aware tracing works
with an abstract ``{axis: size}`` mesh dict, no devices required.  The
end-to-end assertions (bit-identical gradients of the sharded planned twin
vs vanilla ``jax.value_and_grad`` of the sharded function) need 8 devices:
in tier-1 they run through the subprocess wrapper at the bottom
(``XLA_FLAGS=--xla_force_host_platform_device_count=8``); CI also runs this
file directly under that flag — the "8-fake-device sharded smoke".
"""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

import repro
from repro.core import PlanCache, Planner
from repro.core.graph import graph_digest
from repro.core.jaxpr_graph import trace
from repro.core.liveness import vanilla_peak

DN = (((1,), (0,)), ((), ()))


def _mlp(n_layers=6, width=16, batch=8):
    def fn(params, x):
        h = x
        for w in params:
            h = lax.tanh(lax.dot_general(h, w, DN))
        return jnp.sum(h * h)

    key = jax.random.PRNGKey(0)
    params = [
        jax.random.normal(jax.random.fold_in(key, i), (width, width)) * 0.3
        for i in range(n_layers)
    ]
    x = jax.random.normal(jax.random.PRNGKey(1), (batch, width))
    return fn, params, x


def _bits(a, b):
    return all(
        np.array_equal(np.asarray(u), np.asarray(v))
        for u, v in zip(jax.tree_util.tree_leaves(a),
                        jax.tree_util.tree_leaves(b))
    )


# ---------------------------------------------------------------------------
# Accounting (abstract mesh — no devices needed)
# ---------------------------------------------------------------------------


def test_per_device_mv_is_global_over_shards():
    """Every batch-carrying equation output is split 8 ways → M_v = global/8;
    the scalar loss stays replicated."""
    fn, params, x = _mlp()
    n = len(params)
    plain = trace(fn, params, x).graph
    sh = trace(fn, params, x, mesh={"data": 8},
               in_shardings=[P()] * n + [P("data", None)]).graph
    assert plain.n == sh.n
    for a, b in zip(plain.nodes, sh.nodes):
        if a.kind == "reduce_sum":
            assert b.memory == a.memory  # scalar: replicated
        else:
            assert b.memory == a.memory / 8, (a.name, a.memory, b.memory)


def test_mean_style_loss_with_literal_operands():
    """jnp.mean lowers to reduce_sum + div-by-literal: literals are
    unhashable on this JAX and must propagate as replicated, not crash."""

    def fn(params, x):
        h = x
        for w in params:
            h = jnp.tanh(lax.dot_general(h, w, DN))
        return jnp.mean(h * h)

    _, params, x = _mlp()
    sh = trace(fn, params, x, mesh={"data": 8},
               in_shardings=[P()] * len(params) + [P("data", None)]).graph
    assert sh.n > 0  # propagation completed
    pf = repro.plan_function(fn, None, mesh={"data": 8},
                             in_shardings=(None, P("data", None)),
                             planner=Planner(cache=PlanCache()))
    loss, _ = pf(params, x)
    np.testing.assert_allclose(
        np.asarray(loss), np.asarray(fn(params, x)), rtol=1e-6
    )


def test_unknown_primitive_falls_back_to_replicated():
    """Conservative fallback: a reshape (not in the propagation rules)
    replicates — per-device bytes are over-, never under-estimated."""

    def fn(x):
        h = lax.reshape(x, (x.shape[0] * x.shape[1],))
        return jnp.sum(h * h)

    x = jnp.ones((8, 4), jnp.float32)
    sh = trace(fn, x, mesh={"data": 8}, in_shardings=[P("data", None)]).graph
    reshaped = [nd for nd in sh.nodes if nd.kind == "reshape"]
    assert reshaped and reshaped[0].memory == 8 * 4 * 4  # full global bytes


def test_distinct_shardings_distinct_digests():
    """Sharded and unsharded traces (and different shard counts) must not
    collide in the plan cache — per-device M_v is part of the digest."""
    fn, params, x = _mlp()
    n = len(params)
    d_plain = graph_digest(trace(fn, params, x).graph)
    shard8 = [P()] * n + [P("data", None)]
    d8 = graph_digest(trace(fn, params, x, mesh={"data": 8},
                            in_shardings=shard8).graph)
    d4 = graph_digest(trace(fn, params, x, mesh={"data": 4},
                            in_shardings=shard8).graph)
    d8_again = graph_digest(trace(fn, params, x, mesh={"data": 8},
                                  in_shardings=shard8).graph)
    assert len({d_plain, d8, d4}) == 3
    assert d8 == d8_again  # deterministic: same sharding → same key


def test_sharded_and_unsharded_plans_cached_separately():
    fn, params, x = _mlp()
    planner = Planner(cache=PlanCache())
    budget = vanilla_peak(trace(fn, params, x).graph, liveness=False) / 2
    pf_plain = repro.plan_function(fn, budget, planner=planner)
    pf_plain(params, x)
    misses_after_plain = planner.cache.stats()["misses"]
    pf_sh = repro.plan_function(fn, budget, mesh={"data": 8},
                                in_shardings=(None, P("data", None)),
                                planner=planner)
    pf_sh(params, x)
    # the sharded graph is a different planning problem: it must MISS
    assert planner.cache.stats()["misses"] > misses_after_plain


def test_per_device_budget_semantics():
    """The budget the planner enforces is per-device: a budget far below the
    unsharded minimum plans fine when 8 devices share the activations."""
    fn, params, x = _mlp()
    planner = Planner(cache=PlanCache())
    g_plain = trace(fn, params, x).graph
    g_sh = trace(fn, params, x, mesh={"data": 8},
                 in_shardings=[P()] * len(params) + [P("data", None)]).graph
    mfb_plain = planner.min_feasible_budget(g_plain)
    mfb_sh = planner.min_feasible_budget(g_sh)
    assert mfb_sh < mfb_plain / 4  # activations dominate → ≈ /8
    pf = repro.plan_function(fn, mfb_sh, mesh={"data": 8},
                             in_shardings=(None, P("data", None)),
                             planner=planner)
    lowered = pf.lowered_for(params, x)
    assert lowered.plan.peak_memory <= mfb_sh
    assert _bits(pf(params, x), jax.value_and_grad(fn)(params, x))


def test_check_lowering_conformant_on_sharded_carrier():
    """Lowering conformance on a sharded twin: the save-set of the jaxpr
    backend's lowering matches the plan computed on per-device bytes
    (abstract mesh — no devices needed)."""
    from repro.analysis import check_lowering
    from repro.core.lowering.carriers import TracedCarrier

    fn, params, x = _mlp()
    carrier = TracedCarrier.trace(
        fn, (params, x), mesh={"data": 8},
        in_shardings=(None, P("data", None)),
    )
    g = carrier.to_graph()
    planner = Planner(cache=PlanCache())
    rep = planner.plan(g, planner.min_feasible_budget(g))
    assert rep.plan is not None
    report = check_lowering(carrier, rep.plan)
    assert report.ok, str(report.findings)

    # drift detection still works on sharded carriers: a plan for a roomier
    # budget has a different save-set, so checking it against the tight
    # lowering must fail
    from repro.core.liveness import vanilla_peak
    from repro.core.lowering.policy import traced_value_and_grad

    roomy = planner.plan(g, vanilla_peak(g, liveness=True)).plan
    if roomy.cached != rep.plan.cached:
        stale = traced_value_and_grad(carrier, rep.plan)
        r2 = check_lowering(carrier, roomy, lowered=stale)
        assert not r2.ok


# ---------------------------------------------------------------------------
# End to end on 8 (fake) devices
# ---------------------------------------------------------------------------

requires8 = pytest.mark.skipif(
    jax.device_count() < 8,
    reason="needs XLA_FLAGS=--xla_force_host_platform_device_count=8",
)


def _mesh8():
    from repro.parallel.compat import make_mesh

    return make_mesh((8,), ("data",))


@requires8
def test_sharded_planned_twin_bit_identical_to_vanilla():
    """Acceptance: plan_function over a sharded function on an 8-device mesh
    plans against a per-device budget and returns bit-identical loss/grads
    to vanilla jax.value_and_grad of the same sharded function."""
    mesh = _mesh8()
    fn, params, x = _mlp(batch=16)
    xs = NamedSharding(mesh, P("data", None))
    x = jax.device_put(x, xs)
    params = [jax.device_put(w, NamedSharding(mesh, P())) for w in params]

    g_sh = trace(fn, params, x, mesh=mesh,
                 in_shardings=[P()] * len(params) + [P("data", None)]).graph
    budget = vanilla_peak(g_sh, liveness=False) / 2  # per-device halved

    planned = repro.plan_function(
        fn, budget, mesh=mesh, in_shardings=(None, P("data", None)),
        planner=Planner(cache=PlanCache()),
    )
    lowered = planned.lowered_for(params, x)
    assert lowered.backend == "jaxpr"
    assert lowered.plan.overhead > 0  # the per-device budget forces recompute
    assert lowered.plan.peak_memory <= budget

    got = jax.jit(lowered.run)(params, x)
    ref = jax.jit(jax.value_and_grad(fn))(params, x)
    assert _bits(got, ref)


@requires8
def test_sharded_twin_preserves_input_sharding_on_grads():
    """pjit-composability: grads w.r.t. the sharded argument come back in
    the caller's layout (with_sharding_constraint transposes to itself)."""
    mesh = _mesh8()
    fn, params, x = _mlp(batch=16)
    xs = NamedSharding(mesh, P("data", None))
    x = jax.device_put(x, xs)
    planned = repro.plan_function(
        fn, None, argnums=1, mesh=mesh,
        in_shardings=(None, P("data", None)),
        planner=Planner(cache=PlanCache()),
    )
    _, gx = jax.jit(planned.lowered_for(params, x).run)(params, x)
    assert gx.sharding.is_equivalent_to(xs, gx.ndim)
    ref = jax.jit(jax.value_and_grad(fn, argnums=1))(params, x)
    assert _bits(gx, ref[1])


@requires8
def test_check_lowering_on_concrete_mesh_twin():
    """Satellite coverage: conformance over a twin traced with a *concrete*
    8-device mesh + in_shardings — the post-SPMD planning path."""
    from repro.analysis import check_lowering
    from repro.core.lowering.carriers import TracedCarrier

    mesh = _mesh8()
    fn, params, x = _mlp(batch=16)
    carrier = TracedCarrier.trace(
        fn, (params, x), mesh=mesh,
        in_shardings=(None, P("data", None)),
    )
    g = carrier.to_graph()
    planner = Planner(cache=PlanCache())
    rep = planner.plan(g, planner.min_feasible_budget(g))
    assert rep.plan is not None
    report = check_lowering(carrier, rep.plan)
    assert report.ok, str(report.findings)


@requires8
def test_blockgraph_jaxpr_backend_sharded():
    """BlockGraph planned at equation granularity under a mesh: the traced
    carrier sees more nodes than blocks and the grads match vanilla."""
    from repro.core.blockgraph import Block, BlockGraph

    def mk_block(name, src):
        return Block(
            name=name,
            apply=lambda p, h: lax.tanh(lax.dot_general(h, p["w"], DN)),
            inputs=(src,),
            init=lambda rng, shp: {
                "w": jax.random.normal(rng, (shp[-1], shp[-1])) * 0.3
            },
            out_sharding=("batch", None),
        )

    bg = BlockGraph([mk_block(f"b{i}", "x" if i == 0 else f"b{i-1}")
                     for i in range(5)], ["x"], ["b4"])
    params = bg.init(jax.random.PRNGKey(0), {"x": (16, 8)})
    inputs = {"x": jax.random.normal(jax.random.PRNGKey(1), (16, 8))}
    loss = lambda out: jnp.sum(out * out)

    mesh = _mesh8()
    pf = repro.plan_function(bg, None, backend="jaxpr", loss_fn=loss,
                             mesh=mesh, planner=Planner(cache=PlanCache()))
    lowered = pf.lowered_for(params, inputs)
    assert lowered.backend == "jaxpr"
    assert lowered.carrier.to_graph().n > len(bg.blocks)  # eqn granularity

    got = pf(params, inputs)
    ref = jax.value_and_grad(
        lambda p: loss(bg.apply(p, inputs))
    )(params)
    np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                               rtol=1e-6)
    for a, b in zip(jax.tree_util.tree_leaves(got[1]),
                    jax.tree_util.tree_leaves(ref[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-7)


# ---------------------------------------------------------------------------
# Tier-1 wrapper: run the 8-device half in a fresh process under the flag
# (jax pins the device count at first init, so the flag cannot be set here).
# ---------------------------------------------------------------------------


def test_eight_device_suite_in_subprocess():
    if jax.device_count() >= 8:
        pytest.skip("already running under the 8-device flag")
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["JAX_PLATFORMS"] = "cpu"
    env["PYTHONPATH"] = os.path.join(root, "src") + os.pathsep + env.get(
        "PYTHONPATH", "")
    r = subprocess.run(
        [sys.executable, "-m", "pytest", "-q", "-x", "--no-header",
         os.path.abspath(__file__)],
        cwd=root, env=env, capture_output=True, text=True, timeout=900,
    )
    assert r.returncode == 0, f"\nSTDOUT:\n{r.stdout}\nSTDERR:\n{r.stderr}"
    assert " passed" in r.stdout and "error" not in r.stdout.lower()
