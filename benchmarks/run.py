"""Benchmark aggregator: one artifact per paper table/figure + the roofline.

  table1    — Table 1: peak memory per network × method (with liveness)
  table2    — Table 2 (Appendix C): the no-liveness ablation
  fig3      — Figure 3: batch-size vs runtime trade-off
  dp        — §5.1: exact-vs-approx planner runtime
  cache     — plan-cache cold vs warm planning time (≥10× gate)
  roofline  — per-(arch × shape) roofline terms from the dry-run artifacts
  claims    — the paper's quantitative claims checked programmatically

Run everything:   PYTHONPATH=src python -m benchmarks.run
One section:      PYTHONPATH=src python -m benchmarks.run table1
"""

from __future__ import annotations

import sys
import time


def _claims(t1, t2, dp_rows):
    """Check the paper's headline claims on our reproduction."""
    print("\n== Paper-claims check ==")
    ok = True

    def check(name, cond, detail=""):
        nonlocal ok
        ok &= bool(cond)
        print(f"  [{'PASS' if cond else 'FAIL'}] {name} {detail}")

    # 36%-81% reduction band (paper abstract) — best method per network
    reductions = {}
    for net, r in t1.items():
        van = r["vanilla"]
        best = min(
            v for k, v in r.items()
            if k in ("approx_mc", "approx_tc", "exact_mc", "exact_tc", "chen")
            and v is not None
        )
        reductions[net] = 100 * (van - best) / van
    lo, hi = min(reductions.values()), max(reductions.values())
    check("peak-memory reduction band ~ paper's 36-81%",
          20 <= lo and hi <= 95,
          f"(ours {lo:.0f}%-{hi:.0f}%: " +
          ", ".join(f"{k} {v:.0f}%" for k, v in reductions.items()) + ")")

    # DP beats Chen on most networks (Table 1 trend)
    wins = sum(
        1 for r in t1.values()
        if r.get("approx_mc") is not None and r["approx_mc"] <= r["chen"] + 1e-9
    )
    check("ApproxDP+MC <= Chen on most networks", wins >= len(t1) - 1,
          f"({wins}/{len(t1)})")

    # liveness ablation: no-liveness peaks >= with-liveness peaks
    worse = all(
        (t2[n]["approx_mc"] or 0) >= (t1[n]["approx_mc"] or 0) - 1e-9
        for n in t1
    )
    check("removing liveness analysis never helps (Table 2 vs 1)", worse)

    # MC <= TC on peak memory (with liveness), §4.4
    mc_le_tc = sum(
        1 for r in t1.values()
        if r.get("approx_mc") is not None and r.get("approx_tc") is not None
        and r["approx_mc"] <= r["approx_tc"] + 1e-9
    )
    check("MC peak <= TC peak (with liveness) on most networks",
          mc_le_tc >= len(t1) - 2, f"({mc_le_tc}/{len(t1)})")

    # TC overhead <= MC overhead
    t_le = all(
        r["approx_tc_overhead"] <= r["approx_mc_overhead"] + 1e-9
        for r in t1.values()
        if r.get("approx_tc_overhead") is not None
        and r.get("approx_mc_overhead") is not None
    )
    check("TC overhead <= MC overhead", t_le)

    # planner runtime: approx no slower than exact wherever exact ran
    # (ties at the 10 ms scale on small chains are jitter, not signal)
    fast = all(
        r["approx_s"] <= (r["exact_s"] or float("inf")) * 1.1 + 0.05
        for r in dp_rows.values()
    )
    check("approx DP faster than exact DP (10% + 50ms tolerance)", fast)
    return ok


def main() -> int:
    which = sys.argv[1] if len(sys.argv) > 1 else "all"
    t0 = time.perf_counter()
    from . import (
        dp_runtime,
        fig3_tradeoff,
        plan_cache,
        roofline,
        table1_memory,
        table2_no_liveness,
    )

    t1 = t2 = dp_rows = None
    if which in ("all", "table1"):
        t1 = table1_memory.main()
    if which in ("all", "table2"):
        t2 = table2_no_liveness.main()
    if which in ("all", "fig3"):
        fig3_tradeoff.main()
    if which in ("all", "dp"):
        dp_rows = dp_runtime.main()
    if which in ("all", "cache"):
        plan_cache.main()
    if which in ("all", "roofline"):
        try:
            roofline.main("single")
        except Exception as e:
            print(f"roofline skipped: {e} (run launch.dryrun first)")
    if which == "all" and t1 and t2 and dp_rows:
        _claims(t1, t2, dp_rows)
    print(f"\ntotal bench time: {time.perf_counter() - t0:.1f}s")
    return 0


if __name__ == "__main__":
    sys.exit(main())
