"""repro.analysis — static soundness layer over carriers, plans, lowerings.

Three cooperating checkers prove a plan sound *before* lowering (the
ROADMAP's "honest against the compiler" direction):

* :func:`check_graph` — effect/determinism analysis: classify every traced
  equation (pure / prng / effectful / opaque / donated), propagate taint,
  emit ``must_store`` pins the planner consumes as hard constraints
  (``analysis.effects``);
* :func:`check_plan` — plan verifier: topological validity, replay
  soundness, event-simulated peak vs. budget, eq. (1) overhead, per-device
  ``M_v`` — all re-derived independently of the DP
  (``analysis.verifier``);
* :func:`check_lowering` — lowering conformance: the lowered twin's
  ``checkpoint_name`` save-set equals the plan's ``U_k``
  (``analysis.conformance``);
* :func:`check_hlo` — compiler-truth checks over the *compiled* planned
  twin: optimized-HLO heavy-op multiplicity vs. the plan's eq. (1)
  recompute counts, materialization of every cached residual, and the
  memory-drift gate against ``compiled.memory_analysis()``
  (``analysis.hlo``, text parsing in ``analysis.hlo_text``).

The ``plan_lint`` CLI (``python -m repro.analysis``) runs the checkers
over benchmark networks and traced functions and emits a JSON report
(``--hlo`` adds the compiled-artifact stage and the drift-record
artifact).
"""

from __future__ import annotations

from typing import Any

from .conformance import check_lowering
from .hlo import HloAnalysis, analyze_hlo, check_hlo, drift_findings
from .effects import (
    CLASSES,
    EffectAnalysis,
    EqnEffect,
    analyze_effects,
    classify_eqns,
    pin_graph,
)
from .report import Finding, PlanVerificationError, Report
from .verifier import check_graph_memory, check_plan

__all__ = [
    "Finding",
    "PlanVerificationError",
    "Report",
    "CLASSES",
    "EqnEffect",
    "EffectAnalysis",
    "classify_eqns",
    "analyze_effects",
    "pin_graph",
    "check_graph",
    "check_plan",
    "check_graph_memory",
    "check_lowering",
    "check_hlo",
    "analyze_hlo",
    "HloAnalysis",
    "drift_findings",
]


def check_graph(target: Any) -> Report:
    """Effect-analysis report for a traced carrier or ``JaxprGraph``.

    Accepts a ``TracedCarrier``, a ``JaxprGraph``, or a ``ClosedJaxpr``
    (traced with ``jax.make_jaxpr``); pure graphs come back with an empty
    report.  Use :func:`analyze_effects` directly when you also need the
    pins / taint sets.
    """
    from ..core.jaxpr_graph import JaxprGraph, from_jaxpr

    jg = target
    if hasattr(target, "jg"):  # TracedCarrier
        jg = target.jg
    elif not isinstance(target, JaxprGraph):
        jg = from_jaxpr(target)  # ClosedJaxpr
    return analyze_effects(jg).report
